"""In-scan continual distillation (repro.learn, paper §3.4).

Pins the subsystem's two hard invariants plus its moving parts:

  * distill OFF is invisible — a spec without `distill` makes
    bit-identical decisions (chosen + pred_acc) to one with
    distill=False/{"enabled": False} across all three providers, and
    the detector provider's frozen episode never touches LearnState;
  * learning is per-camera — pair harvesting and the optimizer step are
    fleet-size independent (lane 7 learns the same whether it rides an
    F=1 or F=2 fleet), head-only mode leaves every non-head param
    bit-unchanged, and idle cameras (empty ring) are bit-exact no-ops;
  * the pieces round-trip — DistillSpec JSON, learned-params .npz
    checkpoints, and `serve --distill` end to end.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import DetectorConfig
from repro.fleet import FleetRunSpec, run_fleet
from repro.learn import (
    DistillSpec,
    LearnState,
    distill_update,
    harvest_into_buffer,
    init_learn,
    init_pair_buffer,
    normalize_distill,
    select_sent_windows,
)


def _run(distill=None, *, n_cameras=2, n_steps=8, seeds=(3, 5), **kw):
    kw.setdefault("shortlist_k", 9)
    pk = kw.pop("provider_kwargs", {"scene_seeds": list(seeds)})
    spec = FleetRunSpec(
        provider="detector", n_cameras=n_cameras, n_steps=n_steps,
        budget={"fps": 3.0}, seed=3, distill=distill,
        provider_kwargs=pk, **kw)
    return run_fleet(spec)


# ---------------------------------------------------------------------------
# DistillSpec: normalization, validation, JSON
# ---------------------------------------------------------------------------

def test_distill_spec_normalization():
    assert normalize_distill(None) is None
    assert normalize_distill(False) is None
    assert normalize_distill(True) == DistillSpec()
    assert normalize_distill({"enabled": False}) is None
    assert normalize_distill({"lr": 0.01}) == DistillSpec(lr=0.01)
    d = DistillSpec(every=2)
    assert normalize_distill(d) is d


def test_distill_spec_validation():
    with pytest.raises(ValueError, match="optimizer"):
        DistillSpec(optimizer="lion")
    with pytest.raises(ValueError, match="schedule"):
        DistillSpec(schedule="linear")
    with pytest.raises(ValueError, match="harvest"):
        DistillSpec(harvest=9, buffer=4)
    with pytest.raises(ValueError, match="lr"):
        DistillSpec(lr=0.0)
    with pytest.raises(ValueError, match="every"):
        DistillSpec(every=0)


def test_distill_requires_fused_detector_path():
    with pytest.raises(ValueError, match="fused"):
        _run(True, provider_kwargs={"scene_seeds": [3, 5],
                                    "fused": False})


# ---------------------------------------------------------------------------
# invariant 1: distill off is the exact pre-learning program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("provider,kw", [
    ("tables", {}),
    ("scene", {}),
    ("detector", {"shortlist_k": 9}),
])
def test_distill_off_decision_parity(provider, kw):
    """distill=False / {"enabled": False} normalize to None on every
    provider, so the episode compiles the exact frozen program —
    bit-identical decisions, no learning surface on the result."""
    def go(distill):
        spec = FleetRunSpec(provider=provider, n_cameras=2, n_steps=5,
                            budget={"fps": 2.0}, distill=distill, **kw)
        assert spec.distill is None
        return run_fleet(spec)

    base, off, dis = go(None), go(False), go({"enabled": False})
    for r in (off, dis):
        np.testing.assert_array_equal(np.asarray(base.out.chosen),
                                      np.asarray(r.out.chosen))
        np.testing.assert_array_equal(np.asarray(base.out.pred_acc),
                                      np.asarray(r.out.pred_acc))
        assert r.distill_loss is None and r.learned is None
        with pytest.raises(ValueError, match="distill"):
            r.learned_params()


def test_distill_on_changes_detector_decisions():
    """The counterpart pin: learning is NOT decision-invisible — the
    whole point is that trained heads re-rank the shortlist."""
    off, on = _run(None), _run(True)
    assert not np.array_equal(np.asarray(off.out.pred_acc),
                              np.asarray(on.out.pred_acc))


# ---------------------------------------------------------------------------
# invariant 2: learning is per-camera / fleet-size independent
# ---------------------------------------------------------------------------

def test_learning_fleet_size_independent():
    """Camera seed 5 learns the identical trajectory whether it rides an
    F=1 or an F=2 fleet: same decisions, same per-step distill loss,
    same learned head params. Gradients must never cross the fleet
    axis (the per-camera grad clip and vmapped loss guarantee it)."""
    r1 = _run(True, n_cameras=1, seeds=(5,))
    r2 = _run(True, n_cameras=2, seeds=(3, 5))
    np.testing.assert_array_equal(np.asarray(r1.out.chosen[:, 0]),
                                  np.asarray(r2.out.chosen[:, 1]))
    np.testing.assert_allclose(np.asarray(r1.out.pred_acc[:, 0]),
                               np.asarray(r2.out.pred_acc[:, 1]),
                               atol=1e-6)
    _, c1 = r1.learned
    _, c2 = r2.learned
    for l1, l2 in zip(jax.tree.leaves(c1[2].params),
                      jax.tree.leaves(c2[2].params)):
        np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[1]),
                                   atol=1e-6)


def test_harvest_fleet_size_independent():
    """Pure-function level: harvesting camera rows [i] through the ring
    is row-wise — an F=3 harvest equals three F=1 harvests."""
    rng = np.random.default_rng(0)
    f, k, b, h, mb = 3, 6, 4, 2, 5
    buf = init_pair_buffer(f, b, (7,), mb)
    staged = jnp.asarray(rng.normal(size=(f, k, 7)), jnp.float32)
    widx = jnp.asarray(rng.permuted(
        np.tile(np.arange(k), (f, 1)), axis=1), jnp.int32)
    sel = widx[:, :h]
    ok = jnp.asarray([[True, True], [True, False], [False, False]])
    boxes = jnp.asarray(rng.normal(size=(f, h, mb, 4)), jnp.float32)
    cls = jnp.zeros((f, h, mb), jnp.int32)
    val = jnp.asarray(rng.random((f, h, mb)) > 0.5)

    full = harvest_into_buffer(buf, staged, widx, sel, ok, boxes, cls,
                               val)
    for i in range(f):
        sl = jax.tree.map(lambda a, i=i: a[i:i + 1], buf)
        one = harvest_into_buffer(
            sl, staged[i:i + 1], widx[i:i + 1], sel[i:i + 1],
            ok[i:i + 1], boxes[i:i + 1], cls[i:i + 1], val[i:i + 1])
        for la, lb in zip(jax.tree.leaves(one), jax.tree.leaves(
                jax.tree.map(lambda a, i=i: a[i:i + 1], full))):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # rows whose selection was all-invalid write nothing
    assert int(full.ptr[2]) == 0
    assert float(full.weight[2].sum()) == 0.0


def test_select_sent_windows_prefers_chosen_then_sent():
    out = type("O", (), {})()
    out.sent = jnp.asarray([[True, False, True, True]])
    out.pred_acc = jnp.asarray([[0.9, 0.8, 0.2, 0.5]])
    out.chosen = jnp.asarray([2])
    out.zooms = jnp.asarray([[0, 1, 2, 1]])
    widx, ok = select_sent_windows(out, 3, 3)
    # chosen cell 2 outranks the higher-scoring sent cell 0; cell 1
    # was never sent so only 3 sent cells are valid
    assert widx[0, 0] == 2 * 3 + 2          # chosen first
    assert widx[0, 1] == 0 * 3 + 0
    assert bool(ok.all())
    _, ok2 = select_sent_windows(out, 3, 4)
    assert not bool(ok2[0, 3])              # 4th slot has no sent window


# ---------------------------------------------------------------------------
# head-only mode: non-head params bit-unchanged
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return DetectorConfig(name="tiny", img_res=32, patch=16, d_model=16,
                          n_layers=1, n_heads=2, d_ff=32, fpn_dim=8,
                          n_classes=2, max_boxes=4)


def test_head_mask_zeroes_backbone_updates():
    """finetune_update (the rule core/continual.finetune_step delegates
    to) must leave every backbone leaf bit-identical."""
    from repro.core.continual import finetune_step, init_finetune
    from repro.models.detector import detector_init

    cfg = _tiny_cfg()
    params = detector_init(jax.random.PRNGKey(0), cfg)
    opt = init_finetune(params)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    boxes = jnp.tile(jnp.asarray([0.5, 0.5, 0.4, 0.4]), (2, 4, 1))
    cls = jnp.zeros((2, 4), jnp.int32)
    valid = jnp.ones((2, 4), bool)
    new, _, loss = finetune_step(params, opt, cfg, imgs, boxes, cls,
                                 valid)
    assert np.isfinite(float(loss))
    for a, b in zip(jax.tree.leaves(params["backbone"]),
                    jax.tree.leaves(new["backbone"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params["heads"]),
                               jax.tree.leaves(new["heads"])))


def test_episode_backbone_bit_unchanged():
    """Head-only distillation trains ONLY the per-camera heads: the
    merged checkpoint's backbone is the original shared pytree, and the
    heads moved."""
    r = _run(True, n_steps=6)
    provider, _ = r.learned
    learned = r.learned_params(0)
    for a, b in zip(jax.tree.leaves(provider.det_params["backbone"]),
                    jax.tree.leaves(learned["backbone"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(provider.det_params["heads"]),
                               jax.tree.leaves(learned["heads"])))


def test_idle_cameras_are_bit_exact_noops():
    """A camera whose ring is empty passes through distill_update with
    params AND optimizer moments untouched (AdamW decay must not drift
    idle heads), and reports the -1 loss sentinel."""
    cfg = _tiny_cfg()
    from repro.models.detector import detector_init

    det_params = detector_init(jax.random.PRNGKey(0), cfg)
    d = DistillSpec(buffer=2, harvest=1)
    lc = init_learn(d, cfg, det_params, 2, 3)
    g = cfg.img_res // cfg.patch
    # fill only camera 0's ring
    x = jax.random.normal(jax.random.PRNGKey(1), (2, g, g, cfg.fpn_dim))
    buf = lc.buf._replace(
        x=lc.buf.x.at[0].set(x),
        boxes=lc.buf.boxes.at[0, :, 0].set(
            jnp.asarray([0.5, 0.5, 0.5, 0.5])),
        valid=lc.buf.valid.at[0, :, 0].set(True),
        weight=lc.buf.weight.at[0].set(1.0))
    lc = lc._replace(buf=buf)
    new, loss = distill_update(d, cfg, lc)
    assert float(loss[0]) >= 0.0 and float(loss[1]) == -1.0
    for leaf_new, leaf_old in zip(jax.tree.leaves(new.params),
                                  jax.tree.leaves(lc.params)):
        np.testing.assert_array_equal(np.asarray(leaf_new[1]),
                                      np.asarray(leaf_old[1]))
        assert not np.array_equal(np.asarray(leaf_new[0]),
                                  np.asarray(leaf_old[0]))
    for leaf_new, leaf_old in zip(jax.tree.leaves(new.opt.mu),
                                  jax.tree.leaves(lc.opt.mu)):
        if leaf_new.ndim == 0:      # masked-out leaves carry no state
            continue
        np.testing.assert_array_equal(np.asarray(leaf_new[1]),
                                      np.asarray(leaf_old[1]))


# ---------------------------------------------------------------------------
# episode integration: losses, metrics, checkpoints
# ---------------------------------------------------------------------------

def test_distill_episode_losses_and_metrics():
    r = _run(True, n_steps=8, metrics=True)
    loss = np.asarray(r.distill_loss, np.float32)
    assert loss.shape == (8,)
    upd = loss[loss >= 0]
    assert upd.size > 0 and np.isfinite(upd).all()
    # per-step metrics carry the raw [E, F] loss/lr streams
    assert np.asarray(r.metrics["distill_loss"]).shape == (8, 2)
    np.testing.assert_allclose(np.asarray(r.metrics["distill_lr"]),
                               DistillSpec().lr, rtol=1e-6)
    from repro.obs import summarize_metrics
    s = summarize_metrics(r.metrics)
    assert len(s["distill_loss_mean"]) == 2
    assert s["distill_update_steps"][0] > 0


def test_update_cadence_gates_steps():
    r = _run({"every": 4}, n_steps=8)
    loss = np.asarray(r.distill_loss, np.float32)
    # steps are 1-based post-increment: updates land on steps 4, 8 ->
    # indices 3, 7; everything else is the skipped sentinel
    assert (loss[[0, 1, 2, 4, 5, 6]] == -1.0).all()
    assert (loss[[3, 7]] >= 0).all()


def test_learned_params_npz_roundtrip(tmp_path):
    from repro.fleet import load_detector_params

    r = _run(True, n_steps=6)
    path = r.save_learned_params(str(tmp_path / "cam1.npz"), camera=1)
    loaded = load_detector_params(path)
    want = r.learned_params(1)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # and the checkpoint boots a frozen provider (the deploy path)
    r2 = _run(None, n_steps=2, provider_kwargs={
        "scene_seeds": [3, 5], "det_params": path})
    assert r2.out is not None


def test_result_json_drops_learning_payload():
    r = _run(True, n_steps=4)
    d = json.loads(r.to_json())
    assert "learned" not in d
    assert d["distill_loss"] is not None
    from repro.fleet import FleetResult
    rt = FleetResult.from_json(r.to_json())
    assert rt.distill_loss == r.distill_loss
    assert rt.learned is None and rt.spec.distill == DistillSpec()


def test_serve_distill_subprocess():
    """`serve --fleet 2 --provider detector --distill` end to end."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--fps", "2",
         "--duration", "3", "--fleet", "2", "--provider", "detector",
         "--shortlist-k", "9", "--distill"],
        capture_output=True, text=True, timeout=540, env=env, cwd=root)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "distill:" in proc.stdout
    # the flag is rejected without a detector fleet
    bad = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--fps", "2",
         "--duration", "1", "--fleet", "2", "--distill"],
        capture_output=True, text=True, timeout=540, env=env, cwd=root)
    assert bad.returncode != 0
    assert "--distill" in bad.stderr
