"""Cross-pod compressed gradient reduction, end to end under shard_map."""
import os
import subprocess
import sys
import textwrap


def test_crosspod_compressed_allreduce_matches_exact():
    """4 forced devices on a ('pod','data') mesh: int8+EF psum converges
    to the exact mean gradient over steps."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train import compression as comp

        mesh = jax.make_mesh((2, 2), ("pod", "data"))

        def step(g_local, err):
            mean, state = comp.crosspod_allreduce_compressed(
                {"w": g_local}, comp.EFState({"w": err}), axis_name="pod")
            return mean["w"], state.error["w"]

        fn = shard_map(step, mesh=mesh,
                       in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), check_rep=False)

        key = jax.random.PRNGKey(0)
        # 4 device rows = (pod, data) raveled; psum('pod') averages rows
        # {0,1} with {2,3} element-wise per data shard
        g = jax.random.normal(key, (4, 64))
        exact = jnp.tile(g.reshape(2, 2, 64).mean(0), (2, 1))  # [4, 64]

        err = jnp.zeros((4, 64))
        acc = jnp.zeros((4, 64))
        n = 30
        for _ in range(n):
            mean, err = fn(g, err)
            acc = acc + mean
        # EF guarantee: time-average of compressed means -> exact mean
        np.testing.assert_allclose(np.asarray(acc / n), np.asarray(exact),
                                   atol=2e-2)
        # single-shot error is bounded by the quantization step
        np.testing.assert_allclose(np.asarray(mean), np.asarray(exact),
                                   atol=0.1)
        print("COMPRESSION_OK")
    """)
    pypath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": pypath},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "COMPRESSION_OK" in r.stdout, r.stdout + r.stderr
