"""Optimizers, checkpointing, compression, elasticity, fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import optim
from repro.train.fault import HeartbeatTable, RestartPolicy, deadline_for_step

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _toy_params():
    return {"layer": {"w": jax.random.normal(KEY, (8, 4)),
                      "b": jnp.zeros(4)},
            "head": {"w": jax.random.normal(jax.random.fold_in(KEY, 1),
                                            (4, 2))}}


def test_adamw_masking_freezes_leaves():
    params = _toy_params()
    mask = {"layer": {"w": False, "b": False}, "head": {"w": True}}
    state = optim.adamw_init(params, mask)
    grads = jax.tree.map(jnp.ones_like, params)
    p2, _ = optim.adamw_update(params, grads, state, lr=0.1, mask=mask)
    np.testing.assert_array_equal(np.asarray(p2["layer"]["w"]),
                                  np.asarray(params["layer"]["w"]))
    assert bool(jnp.any(p2["head"]["w"] != params["head"]["w"]))
    # masked leaves carry scalar (empty) optimizer state — the 97% saving
    assert state.mu["layer"]["w"].shape == ()
    assert state.mu["head"]["w"].shape == (4, 2)


def test_adamw_descends_quadratic():
    p = {"w": jnp.array([3.0, -2.0])}
    st_ = optim.adamw_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st_ = optim.adamw_update(p, g, st_, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_adafactor_memory_is_factored():
    p = {"w": jnp.zeros((512, 256))}
    st_ = optim.adafactor_init(p)
    assert st_.vr["w"].shape == (512,)
    assert st_.vc["w"].shape == (256,)
    assert st_.v["w"].shape == ()
    # state is ~(n+m)/(n*m) of AdamW's
    adam_state = 2 * 512 * 256
    fact_state = 512 + 256
    assert fact_state < adam_state / 100


def test_adafactor_descends_quadratic():
    p = {"w": jnp.full((4, 4), 3.0)}
    st_ = optim.adafactor_init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st_ = optim.adafactor_update(p, g, st_, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_cosine_schedule_shape():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=0.01)
    assert float(lr(100)) == pytest.approx(0.0, abs=0.01)
    assert float(lr(55)) > float(lr(90))


# ---------------------------------------------------------------------------
# gradient compression + error feedback
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    q, scale = comp.quantize_int8(x)
    err = jnp.abs(comp.dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_error_feedback_is_contraction():
    """With a constant gradient, EF error stays bounded and the mean
    dequantized signal converges to the true gradient."""
    g = {"w": jax.random.normal(KEY, (128,))}
    state = comp.init_ef(g)
    acc = jnp.zeros((128,))
    n = 50
    for _ in range(n):
        qs, scales, state = comp.compress(g, state)
        acc = acc + comp.decompress(qs, scales)["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               atol=1e-2)
    assert float(jnp.abs(state.error.error["w"]
                         if hasattr(state.error, "error")
                         else state.error["w"]).max()) < 1.0


def test_compression_wire_bytes():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    qs, scales, _ = comp.compress(g, comp.init_ef(g))
    wire = qs["w"].size * 1 + 4
    assert wire < g["w"].size * 4 / 3.9


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jax.random.normal(KEY, (16, 8)),
                       "b": jnp.arange(8, dtype=jnp.float32)},
            "step": jnp.asarray(7)}
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 100, tree)
    assert ckpt.latest_step(d) == 100
    restored, manifest = ckpt.restore(d, 100, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 100


def test_checkpoint_prune_keeps_newest(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.zeros(4)}
    for s in [10, 20, 30, 40, 50]:
        ckpt.save(d, s, tree)
    ckpt.prune_old(d, keep=2)
    assert ckpt.latest_step(d) == 50
    remaining = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(remaining) == 2


def test_checkpoint_atomicity(tmp_path):
    """A half-written tmp dir must never be visible as a checkpoint."""
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.zeros(4)}
    ckpt.save(d, 5, tree)
    os.makedirs(os.path.join(d, "step_00000009.tmp-0"), exist_ok=True)
    assert ckpt.latest_step(d) == 5


# ---------------------------------------------------------------------------
# fault handling
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_hosts():
    hb = HeartbeatTable(n_hosts=4, dead_after_s=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, 0.5, now=now)
    hb.beat(0, 0.5, now=now + 20)
    dead = hb.dead_hosts(now=now + 20)
    assert set(dead) == {1, 2, 3}


def test_straggler_detection():
    hb = HeartbeatTable(n_hosts=4)
    for step in range(20):
        for h in range(4):
            hb.beat(h, 0.1 if h != 2 else 0.5)
    assert hb.stragglers(tolerance=1.5) == [2]


def test_restart_policy_prefers_elastic():
    pol = RestartPolicy()
    assert pol.decide(0, 256, 16) == "continue"
    assert pol.decide(16, 256, 16) == "elastic_shrink"   # 240 % 16 == 0
    assert pol.decide(15, 256, 16) == "full_restart"     # 241 % 16 != 0


def test_restart_backoff_grows():
    pol = RestartPolicy(backoff_base_s=1.0)
    assert pol.backoff_s() < pol.backoff_s() < pol.backoff_s()


def test_deadline_from_history():
    assert deadline_for_step([0.1] * 50) == pytest.approx(0.2, abs=0.05) \
        or deadline_for_step([0.1] * 50) >= 0.2 * 0.9
    assert deadline_for_step([]) > 0


# ---------------------------------------------------------------------------
# elastic re-sharding
# ---------------------------------------------------------------------------

def test_rebalance_batch():
    from repro.train.elastic import rebalance_batch, valid_submesh_sizes
    assert rebalance_batch(256, old_dp=16, new_dp=12) == 192
    assert 15 in valid_submesh_sizes(240, model_parallel=16)
