"""repro.fleet vs the numpy reference controller.

The headline test drives MadEyeController.step and the F=1 fleet step in
lockstep on the same scene substrate and asserts the controllers make
identical decisions — explored cells (and their path order), zoom levels,
and the frames sent to the backend — every timestep. Unit-level tests pin
the batched shape ops and the MST walk to their core/ counterparts on
randomized states.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core import search as search_mod
from repro.core.grid import contiguous
from repro.core.madeye import MadEyeController
from repro.core.path import planner_for
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.fleet import (
    build_episode_tables,
    fleet_config,
    fleet_statics,
    init_fleet,
    run_fleet_episode,
    workload_spec,
)
from repro.fleet import shape_ops
from repro.fleet.step import FleetObs, _walk, fleet_step
from repro.serving import NetworkTrace, detection_tables
from repro.serving.accuracy import workload_acc_table
from repro.serving.pipeline import _observation_from_tables

GRID = DEFAULT_GRID
N = GRID.n_cells
WORKLOAD = Workload((
    Query("yolov4", "person", "count"),
    Query("ssd", "car", "detect"),
    Query("frcnn", "person", "binary"),
    Query("tiny-yolov4", "person", "agg_count"),
))
BUDGET = BudgetConfig(fps=2.0)
MISS = 0.12


@pytest.fixture(scope="module")
def substrate():
    video = build_video(GRID, SceneConfig(fps=15, seed=3), 8.0)
    tables = detection_tables(video, WORKLOAD)
    acc = workload_acc_table(video, WORKLOAD, tables)
    trace = NetworkTrace.fixed(24.0, 20.0, video.n_frames)
    ep = build_episode_tables(video, WORKLOAD, tables, BUDGET, trace,
                              approx_miss=MISS, acc_table=acc)
    return video, tables, acc, trace, ep


# ---------------------------------------------------------------------------
# end-to-end F=1 decision parity
# ---------------------------------------------------------------------------

def test_f1_step_parity_with_numpy_controller(substrate):
    video, tables, acc, trace, ep = substrate
    ctrl = MadEyeController(GRID, WORKLOAD, budget=BUDGET)
    stride = max(1, int(round(video.fps / BUDGET.fps)))
    frames = list(range(0, video.n_frames, stride))

    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    st = init_fleet(GRID, 1)

    for ei, t in enumerate(frames):
        ctrl.report_network(trace.observed_mbps(t), trace.rtt_s)

        def observe(cells, zooms, _t=t):
            return [_observation_from_tables(tables, WORKLOAD, GRID, _t, c,
                                             int(zi), MISS)
                    for c, zi in zip(cells, zooms)]

        res = ctrl.step(observe)
        zoom_of = {c: int(z) for c, z in zip(res.explored, res.zooms)}
        if len(res.explored) > 1:
            # pipeline.run_madeye's rank-agreement feedback, quantized to
            # f32 (the ranking precision both controllers share)
            true_vals = np.asarray(
                [acc[t, c, zoom_of[c]] for c in res.explored], np.float32)
            pred32 = np.asarray(res.pred_acc, np.float32)
            agree = float(res.explored[int(np.argmax(pred32))]
                          == res.explored[int(np.argmax(true_vals))])
            ctrl.report_train_acc(0.9 * ctrl.train_acc + 0.1 * agree)

        st, out = fleet_step(cfg, spec, statics, st,
                             FleetObs(*[x[ei] for x in ep]))

        j_order = [int(x) for x in np.asarray(out.order[0])][
            : int(out.n_explored[0])]
        assert j_order == list(res.explored), f"path order @ step {ei}"
        j_sent = set(np.flatnonzero(np.asarray(out.sent[0])).tolist())
        assert j_sent == set(res.sent), f"sent set @ step {ei}"
        zooms = np.asarray(out.zooms[0])
        assert {c: int(zooms[c]) for c in j_order} == zoom_of, \
            f"zooms @ step {ei}"


def test_fleet_lanes_are_independent_and_identical(substrate):
    """Identical cameras fed identical observations stay in lockstep —
    the fleet axis is pure batch, no cross-camera leakage."""
    _, _, _, _, ep = substrate
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    st = init_fleet(GRID, 5)
    _, out = run_fleet_episode(cfg, spec, statics, st, ep)
    explored = np.asarray(out.explored)
    sent = np.asarray(out.sent)
    for lane in range(1, 5):
        np.testing.assert_array_equal(explored[:, lane], explored[:, 0])
        np.testing.assert_array_equal(sent[:, lane], sent[:, 0])


# ---------------------------------------------------------------------------
# randomized unit parity for the batched shape ops + walk
# ---------------------------------------------------------------------------

def _random_contiguous_masks(rng, f, max_size):
    masks = np.zeros((f, N), bool)
    for i in range(f):
        size = rng.integers(1, max_size + 1)
        cur = int(rng.integers(N))
        masks[i, cur] = True
        while masks[i].sum() < size:
            frontier = np.flatnonzero(
                (GRID.neighbor_mask[masks[i]].any(0)) & ~masks[i])
            masks[i, rng.choice(frontier)] = True
    return masks


def _random_state(rng, f):
    masks = _random_contiguous_masks(rng, f, 9)
    labels = rng.uniform(0.01, 1.0, (f, N)).astype(np.float32)
    cents = rng.uniform(0.0, [150.0, 75.0], (f, N, 2)).astype(np.float32)
    has = rng.random((f, N)) < 0.6
    return masks, labels, cents, has


def test_evolve_shape_matches_core_search():
    rng = np.random.default_rng(7)
    masks, labels, cents, has = _random_state(rng, 32)
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    out = np.asarray(shape_ops.evolve_shape(
        cfg, statics, jnp.asarray(masks), jnp.asarray(labels),
        jnp.asarray(cents), jnp.asarray(has)))
    for i in range(masks.shape[0]):
        ref = search_mod.evolve_shape(GRID, masks[i], labels[i].astype(float),
                                      cents[i].astype(float), has[i])
        np.testing.assert_array_equal(out[i], ref, err_msg=f"camera {i}")


def test_resize_shape_matches_core_search():
    rng = np.random.default_rng(11)
    masks, labels, cents, has = _random_state(rng, 32)
    targets = rng.integers(1, 13, 32)
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    out = np.asarray(shape_ops.resize_shape(
        cfg, statics, jnp.asarray(masks), jnp.asarray(labels),
        jnp.asarray(cents), jnp.asarray(has), jnp.asarray(targets)))
    for i in range(masks.shape[0]):
        ref = search_mod.resize_shape(GRID, masks[i],
                                      labels[i].astype(float),
                                      cents[i].astype(float), has[i],
                                      int(targets[i]))
        np.testing.assert_array_equal(out[i], ref, err_msg=f"camera {i}")
        assert contiguous(out[i], GRID)


def test_seed_shape_matches_core_search():
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    sizes = np.arange(1, N + 1)
    centers = np.arange(N)[: sizes.size]
    out = np.asarray(shape_ops.seed_shape(
        statics, cfg, jnp.asarray(sizes), jnp.asarray(centers)))
    for i, (s, c) in enumerate(zip(sizes, centers)):
        ref = search_mod.seed_shape(GRID, int(s), int(c))
        np.testing.assert_array_equal(out[i], ref, err_msg=f"size {s}")


def test_walk_matches_path_planner():
    rng = np.random.default_rng(13)
    masks = _random_contiguous_masks(rng, 48, 12)
    starts = rng.integers(0, N, 48).astype(np.int32)
    statics = fleet_statics(GRID)
    order, cnt, t_deg = _walk(statics, jnp.asarray(masks),
                              jnp.asarray(starts))
    order, cnt, t_deg = (np.asarray(order), np.asarray(cnt),
                         np.asarray(t_deg))
    planner = planner_for(GRID)
    for i in range(masks.shape[0]):
        ref = planner.subtree_walk(masks[i], int(starts[i]))
        got = [int(x) for x in order[i][: cnt[i]]]
        assert got == ref, f"walk {i}"
        t_ref = planner.path_time(ref, 1.0, from_cell=int(starts[i]))
        np.testing.assert_allclose(t_deg[i], t_ref, rtol=1e-5)


def test_first_removable_matches_shrink_rule():
    rng = np.random.default_rng(17)
    masks = _random_contiguous_masks(rng, 32, 10)
    labels = rng.uniform(0.01, 1.0, (32, N)).astype(np.float32)
    statics = fleet_statics(GRID)
    picks = np.asarray(shape_ops.first_removable(
        jnp.asarray(masks), jnp.asarray(labels), statics.neighbor8))
    from repro.core.grid import removal_keeps_contiguity
    for i in range(32):
        if masks[i].sum() < 2:
            continue
        cand = sorted(np.flatnonzero(masks[i]), key=lambda c: labels[i][c])
        want = next((c for c in cand
                     if removal_keeps_contiguity(masks[i], int(c), GRID)),
                    cand[0])
        assert int(picks[i]) == int(want), f"camera {i}"
