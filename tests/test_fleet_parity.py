"""repro.fleet vs the numpy reference controller.

The headline test drives MadEyeController.step and the F=1 fleet step in
lockstep on the same scene substrate and asserts the controllers make
identical decisions — explored cells (and their path order), zoom levels,
and the frames sent to the backend — every timestep. Unit-level tests pin
the batched shape ops and the MST walk to their core/ counterparts on
randomized states.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core import search as search_mod
from repro.core.grid import contiguous
from repro.core.madeye import MadEyeController
from repro.core.path import planner_for
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.fleet import (
    build_episode_tables,
    fleet_config,
    fleet_network_traces,
    fleet_statics,
    init_fleet,
    make_scene_provider,
    materialize_scene_tables,
    run_fleet_episode,
    workload_spec,
)
from repro.fleet import shape_ops
from repro.fleet.step import FleetObs, _walk, fleet_step
from repro.serving import NetworkTrace, detection_tables
from repro.serving.accuracy import workload_acc_table
from repro.serving.pipeline import _observation_from_tables

GRID = DEFAULT_GRID
N = GRID.n_cells
WORKLOAD = Workload((
    Query("yolov4", "person", "count"),
    Query("ssd", "car", "detect"),
    Query("frcnn", "person", "binary"),
    Query("tiny-yolov4", "person", "agg_count"),
))
BUDGET = BudgetConfig(fps=2.0)
MISS = 0.12


@pytest.fixture(scope="module")
def substrate():
    video = build_video(GRID, SceneConfig(fps=15, seed=3), 8.0)
    tables = detection_tables(video, WORKLOAD)
    acc = workload_acc_table(video, WORKLOAD, tables)
    trace = NetworkTrace.fixed(24.0, 20.0, video.n_frames)
    ep = build_episode_tables(video, WORKLOAD, tables, BUDGET, trace,
                              approx_miss=MISS, acc_table=acc)
    return video, tables, acc, trace, ep


# ---------------------------------------------------------------------------
# end-to-end F=1 decision parity
# ---------------------------------------------------------------------------

def test_f1_step_parity_with_numpy_controller(substrate):
    video, tables, acc, trace, ep = substrate
    ctrl = MadEyeController(GRID, WORKLOAD, budget=BUDGET)
    stride = max(1, int(round(video.fps / BUDGET.fps)))
    frames = list(range(0, video.n_frames, stride))

    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    st = init_fleet(GRID, 1)

    for ei, t in enumerate(frames):
        ctrl.report_network(trace.observed_mbps(t), trace.rtt_s)

        def observe(cells, zooms, _t=t):
            return [_observation_from_tables(tables, WORKLOAD, GRID, _t, c,
                                             int(zi), MISS)
                    for c, zi in zip(cells, zooms)]

        res = ctrl.step(observe)
        zoom_of = {c: int(z) for c, z in zip(res.explored, res.zooms)}
        if len(res.explored) > 1:
            # pipeline.run_madeye's rank-agreement feedback, quantized to
            # f32 (the ranking precision both controllers share)
            true_vals = np.asarray(
                [acc[t, c, zoom_of[c]] for c in res.explored], np.float32)
            pred32 = np.asarray(res.pred_acc, np.float32)
            agree = float(res.explored[int(np.argmax(pred32))]
                          == res.explored[int(np.argmax(true_vals))])
            ctrl.report_train_acc(0.9 * ctrl.train_acc + 0.1 * agree)

        st, out = fleet_step(cfg, spec, statics, st,
                             FleetObs(*[x[ei] for x in ep]))

        j_order = [int(x) for x in np.asarray(out.order[0])][
            : int(out.n_explored[0])]
        assert j_order == list(res.explored), f"path order @ step {ei}"
        j_sent = set(np.flatnonzero(np.asarray(out.sent[0])).tolist())
        assert j_sent == set(res.sent), f"sent set @ step {ei}"
        zooms = np.asarray(out.zooms[0])
        assert {c: int(zooms[c]) for c in j_order} == zoom_of, \
            f"zooms @ step {ei}"


def test_fleet_lanes_are_independent_and_identical(substrate):
    """Identical cameras fed identical observations stay in lockstep —
    the fleet axis is pure batch, no cross-camera leakage."""
    _, _, _, _, ep = substrate
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    st = init_fleet(GRID, 5)
    _, out = run_fleet_episode(cfg, spec, statics, st, ep)
    explored = np.asarray(out.explored)
    sent = np.asarray(out.sent)
    for lane in range(1, 5):
        np.testing.assert_array_equal(explored[:, lane], explored[:, 0])
        np.testing.assert_array_equal(sent[:, lane], sent[:, 0])


# ---------------------------------------------------------------------------
# observation-provider seam: scene-backed vs tables-backed decisions
# ---------------------------------------------------------------------------

DECISION_FIELDS = ("explored", "order", "n_explored", "zooms", "sent",
                   "k_send")


def test_scene_provider_matches_tables_provider():
    """A homogeneous fleet driven by the device-resident scene provider
    makes decisions identical, step for step, to the tables-backed path
    scanning the materialized record of the very same observation stream
    — the provider seam changes where observations come from, never what
    the controller does with them."""
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    provider, st0 = make_scene_provider(
        GRID, WORKLOAD, cfg, n_cameras=3, n_steps=14,
        scene_seeds=[7, 7, 7])
    _, out_scene = run_fleet_episode(cfg, spec, statics, st0, provider)
    tables = materialize_scene_tables(cfg, spec, statics, st0, provider)
    _, out_tab = run_fleet_episode(cfg, spec, statics, st0, tables)
    for name in DECISION_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(out_scene, name)),
            np.asarray(getattr(out_tab, name)), err_msg=name)
    np.testing.assert_allclose(np.asarray(out_scene.pred_acc),
                               np.asarray(out_tab.pred_acc), atol=1e-6)


def test_scene_provider_heterogeneous_end_to_end():
    """Per-camera scene configs (seed/density/speed) + per-camera network
    traces run inside one scan; cameras genuinely diverge while identical
    cameras stay in lockstep."""
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    provider, st0 = make_scene_provider(
        GRID, WORKLOAD, cfg, n_cameras=4, n_steps=14,
        scene_seeds=[1, 9, 9, 4], person_speed=[0.8, 1.5, 1.5, 2.2],
        n_people=[4, 12, 12, 14], n_cars=[2, 6, 6, 8],
        mbps=[12.0, 24.0, 24.0, 60.0], net_seed=None)
    _, out = run_fleet_episode(cfg, spec, statics, st0, provider)
    explored = np.asarray(out.explored)
    sent = np.asarray(out.sent)
    # cameras 1 and 2 are configured identically -> lockstep
    np.testing.assert_array_equal(explored[:, 1], explored[:, 2])
    np.testing.assert_array_equal(sent[:, 1], sent[:, 2])
    # camera 0 watches a different world -> decisions diverge
    assert not np.array_equal(explored[:, 0], explored[:, 1])


def test_per_camera_network_traces_drive_budget():
    """[E, F] traces reach the per-camera budget stage: a starved camera
    ships fewer frames than a fat-pipe camera on the same scene."""
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    provider, st0 = make_scene_provider(
        GRID, WORKLOAD, cfg, n_cameras=2, n_steps=16,
        scene_seeds=[3, 3], mbps=[1.2, 60.0])
    assert provider.mbps.shape == (16, 2)
    _, out = run_fleet_episode(cfg, spec, statics, st0, provider)
    k = np.asarray(out.k_send)
    assert k[:, 0].sum() < k[:, 1].sum()


def test_fleet_network_traces_shapes():
    m, r = fleet_network_traces(8, mbps=24.0)
    assert m.shape == (8,) and r.shape == (8,)
    m, r = fleet_network_traces(8, 5, mbps=np.full(5, 24.0), seed=0)
    assert m.shape == (8, 5) and r.shape == (8, 5)
    m = np.asarray(m)
    assert (m >= 1.0).all() and (m <= 48.0).all()
    assert not np.allclose(m[:, 0], m[:, 1])    # per-camera streams


# ---------------------------------------------------------------------------
# unified entry (run_fleet / FleetRunSpec) vs the pre-refactor entries
# ---------------------------------------------------------------------------

def _assert_same_decisions(out_a, out_b):
    for name in DECISION_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(out_a, name)),
            np.asarray(getattr(out_b, name)), err_msg=name)


def test_unified_entry_matches_legacy_tables_entry(substrate):
    """run_fleet(tables spec) == engine.run_fleet_controller == the
    runner-level run_fleet_episode over hand-built EpisodeTables,
    step for step on the pinned seed-3 substrate."""
    from repro.fleet import FleetRunSpec, run_fleet
    from repro.serving.engine import run_fleet_controller

    video, tables, acc, trace, ep = substrate
    cfg = fleet_config(GRID, BUDGET)
    st = init_fleet(GRID, 2)
    _, out_runner = run_fleet_episode(cfg, workload_spec(WORKLOAD),
                                      fleet_statics(GRID), st, ep)
    _, out_engine = run_fleet_controller(video, WORKLOAD, tables, BUDGET,
                                         trace, n_cameras=2,
                                         acc_table=acc)
    res = run_fleet(FleetRunSpec.from_objects(
        "tables", n_cameras=2, grid=GRID, workload=WORKLOAD,
        budget=BUDGET, video=video, tables=tables, trace=trace,
        acc_table=acc))
    _assert_same_decisions(out_runner, out_engine)
    _assert_same_decisions(out_runner, res.out)


def test_unified_entry_matches_legacy_scene_entry():
    """run_fleet(scene spec) == engine.run_fleet_scene_controller ==
    make_scene_provider + run_fleet_episode, step for step (pinned
    scene seeds)."""
    from repro.fleet import FleetRunSpec, run_fleet
    from repro.serving.engine import run_fleet_scene_controller

    kw = dict(n_cameras=2, n_steps=8, seed=11, scene_seeds=[4, 7])
    cfg = fleet_config(GRID, BUDGET)
    provider, st0 = make_scene_provider(GRID, WORKLOAD, cfg, **kw)
    _, out_runner = run_fleet_episode(cfg, workload_spec(WORKLOAD),
                                      fleet_statics(GRID), st0, provider)
    _, out_engine = run_fleet_scene_controller(GRID, WORKLOAD, BUDGET,
                                               **kw)
    res = run_fleet(FleetRunSpec.from_objects(
        "scene", grid=GRID, workload=WORKLOAD, budget=BUDGET, **kw))
    _assert_same_decisions(out_runner, out_engine)
    _assert_same_decisions(out_runner, res.out)
    # the typed result summarizes the same episode
    np.testing.assert_array_equal(
        np.asarray(res.chosen), np.asarray(out_runner.chosen))
    assert res.accuracy == pytest.approx(
        float(np.asarray(out_runner.acc_chosen).mean()))


def test_unified_entry_matches_legacy_detector_entry():
    """run_fleet(detector spec) == engine.run_fleet_detector_controller
    == make_detector_provider + run_fleet_episode, step for step."""
    from repro.fleet import FleetRunSpec, make_detector_provider, run_fleet
    from repro.serving.engine import run_fleet_detector_controller

    kw = dict(n_cameras=1, n_steps=4, seed=0, scene_seeds=[5])
    cfg = fleet_config(GRID, BUDGET)
    provider, st0 = make_detector_provider(GRID, WORKLOAD, cfg, **kw)
    _, out_runner = run_fleet_episode(cfg, workload_spec(WORKLOAD),
                                      fleet_statics(GRID), st0, provider)
    _, out_engine = run_fleet_detector_controller(GRID, WORKLOAD, BUDGET,
                                                  **kw)
    res = run_fleet(FleetRunSpec.from_objects(
        "detector", grid=GRID, workload=WORKLOAD, budget=BUDGET, **kw))
    _assert_same_decisions(out_runner, out_engine)
    _assert_same_decisions(out_runner, res.out)


# ---------------------------------------------------------------------------
# detector fast path: fused exhaustive pipeline vs the chunked reference
# ---------------------------------------------------------------------------

def test_detector_fused_exhaustive_matches_chunked_reference():
    """The candidate-sparse fused pipeline at shortlist_k = N*Z (score
    everything) makes decisions identical, step for step, to the
    pre-shortlist serial-lax.map chunk loop (`fused=False`, the retained
    pre-PR pipeline) — same explored cells, path order, zooms, sent
    frames, and bit-equal predicted accuracies. The fast path changes
    how candidates are rendered and scored (fused crop->token stage, one
    batched [F*K] forward), never what the controller sees."""
    import dataclasses

    from repro.fleet import make_detector_provider

    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)
    provider, st0 = make_detector_provider(
        GRID, WORKLOAD, cfg, n_cameras=2, n_steps=4, scene_seeds=[5, 9])
    assert provider.fused and provider.shortlist_k == N * 3
    _, out_fast = run_fleet_episode(cfg, spec, statics, st0, provider)
    _, out_ref = run_fleet_episode(cfg, spec, statics, st0,
                                   dataclasses.replace(provider,
                                                       fused=False))
    for name in DECISION_FIELDS + ("chosen",):
        np.testing.assert_array_equal(
            np.asarray(getattr(out_fast, name)),
            np.asarray(getattr(out_ref, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(out_fast.pred_acc),
                                  np.asarray(out_ref.pred_acc))
    np.testing.assert_array_equal(np.asarray(out_fast.acc_chosen),
                                  np.asarray(out_ref.acc_chosen))


def test_detector_shortlist_covers_search_reachable_cells():
    """shortlist_windows keeps every window the shape search can reach
    this step from the carried state: the current shape and its
    8-neighbor ring rank ahead of everything else, so with K/Z >= the
    reachable set they are all shortlisted; the remaining slots go to
    the top-EWMA cells (reseed/scout targets)."""
    from repro.fleet import init_fleet, shortlist_windows

    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    st = init_fleet(GRID, 2)
    shape = np.asarray(st.shape[0])
    ring = (np.asarray(statics.neighbor8)[shape].any(0)) & ~shape
    reach = np.flatnonzero(shape | ring)
    kc = len(reach) + 2
    widx = np.asarray(shortlist_windows(cfg, st, statics.neighbor8,
                                        kc * 3))
    assert widx.shape == (2, kc * 3)
    cells = set((widx[0] // 3).tolist())
    assert set(reach.tolist()) <= cells
    # all zooms of every kept cell ride along
    assert set(widx[0].tolist()) == {c * 3 + z for c in cells
                                     for z in range(3)}


# ---------------------------------------------------------------------------
# randomized unit parity for the batched shape ops + walk
# ---------------------------------------------------------------------------

def _random_contiguous_masks(rng, f, max_size):
    masks = np.zeros((f, N), bool)
    for i in range(f):
        size = rng.integers(1, max_size + 1)
        cur = int(rng.integers(N))
        masks[i, cur] = True
        while masks[i].sum() < size:
            frontier = np.flatnonzero(
                (GRID.neighbor_mask[masks[i]].any(0)) & ~masks[i])
            masks[i, rng.choice(frontier)] = True
    return masks


def _random_state(rng, f):
    masks = _random_contiguous_masks(rng, f, 9)
    labels = rng.uniform(0.01, 1.0, (f, N)).astype(np.float32)
    cents = rng.uniform(0.0, [150.0, 75.0], (f, N, 2)).astype(np.float32)
    has = rng.random((f, N)) < 0.6
    return masks, labels, cents, has


def test_evolve_shape_matches_core_search():
    rng = np.random.default_rng(7)
    masks, labels, cents, has = _random_state(rng, 32)
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    out = np.asarray(shape_ops.evolve_shape(
        cfg, statics, jnp.asarray(masks), jnp.asarray(labels),
        jnp.asarray(cents), jnp.asarray(has)))
    for i in range(masks.shape[0]):
        ref = search_mod.evolve_shape(GRID, masks[i], labels[i].astype(float),
                                      cents[i].astype(float), has[i])
        np.testing.assert_array_equal(out[i], ref, err_msg=f"camera {i}")


def test_resize_shape_matches_core_search():
    rng = np.random.default_rng(11)
    masks, labels, cents, has = _random_state(rng, 32)
    targets = rng.integers(1, 13, 32)
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    out = np.asarray(shape_ops.resize_shape(
        cfg, statics, jnp.asarray(masks), jnp.asarray(labels),
        jnp.asarray(cents), jnp.asarray(has), jnp.asarray(targets)))
    for i in range(masks.shape[0]):
        ref = search_mod.resize_shape(GRID, masks[i],
                                      labels[i].astype(float),
                                      cents[i].astype(float), has[i],
                                      int(targets[i]))
        np.testing.assert_array_equal(out[i], ref, err_msg=f"camera {i}")
        assert contiguous(out[i], GRID)


def test_seed_shape_matches_core_search():
    cfg = fleet_config(GRID, BUDGET)
    statics = fleet_statics(GRID)
    sizes = np.arange(1, N + 1)
    centers = np.arange(N)[: sizes.size]
    out = np.asarray(shape_ops.seed_shape(
        statics, cfg, jnp.asarray(sizes), jnp.asarray(centers)))
    for i, (s, c) in enumerate(zip(sizes, centers)):
        ref = search_mod.seed_shape(GRID, int(s), int(c))
        np.testing.assert_array_equal(out[i], ref, err_msg=f"size {s}")


def test_walk_matches_path_planner():
    rng = np.random.default_rng(13)
    masks = _random_contiguous_masks(rng, 48, 12)
    starts = rng.integers(0, N, 48).astype(np.int32)
    statics = fleet_statics(GRID)
    order, cnt, t_deg = _walk(statics, jnp.asarray(masks),
                              jnp.asarray(starts))
    order, cnt, t_deg = (np.asarray(order), np.asarray(cnt),
                         np.asarray(t_deg))
    planner = planner_for(GRID)
    for i in range(masks.shape[0]):
        ref = planner.subtree_walk(masks[i], int(starts[i]))
        got = [int(x) for x in order[i][: cnt[i]]]
        assert got == ref, f"walk {i}"
        t_ref = planner.path_time(ref, 1.0, from_cell=int(starts[i]))
        np.testing.assert_allclose(t_deg[i], t_ref, rtol=1e-5)


def test_first_removable_matches_shrink_rule():
    rng = np.random.default_rng(17)
    masks = _random_contiguous_masks(rng, 32, 10)
    labels = rng.uniform(0.01, 1.0, (32, N)).astype(np.float32)
    statics = fleet_statics(GRID)
    picks = np.asarray(shape_ops.first_removable(
        jnp.asarray(masks), jnp.asarray(labels), statics.neighbor8))
    from repro.core.grid import removal_keeps_contiguity
    for i in range(32):
        if masks[i].sum() < 2:
            continue
        cand = sorted(np.flatnonzero(masks[i]), key=lambda c: labels[i][c])
        want = next((c for c in cand
                     if removal_keeps_contiguity(masks[i], int(c), GRID)),
                    cand[0])
        assert int(picks[i]) == int(want), f"camera {i}"
