"""Unit tests for EWMA labels, ranking semantics, zoom, continual replay."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ewma, rank, zoom as zoom_mod
from repro.core.continual import ReplayBuffer, balanced_counts
from repro.core.grid import DEFAULT_GRID
from repro.core.rank import Query, Workload

GRID = DEFAULT_GRID
N = GRID.n_cells


# ---------------------------------------------------------------------------
# EWMA (JAX fleet variant)
# ---------------------------------------------------------------------------

def test_ewma_first_visit_sets_value():
    st = ewma.init_state(N)
    visited = jnp.zeros(N, bool).at[3].set(True)
    vals = jnp.zeros(N).at[3].set(0.7)
    st = ewma.update(st, visited, vals)
    assert float(st.acc[3]) == pytest.approx(0.7)
    assert float(st.delta[3]) == 0.0
    assert float(st.seen[3]) == 1.0
    assert float(st.acc[0]) == 0.0


def test_ewma_converges_to_constant_signal():
    st = ewma.init_state(N)
    visited = jnp.ones(N, bool)
    vals = jnp.full(N, 0.5)
    for _ in range(50):
        st = ewma.update(st, visited, vals)
    np.testing.assert_allclose(np.asarray(st.acc), 0.5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st.delta), 0.0, atol=1e-3)


def test_ewma_labels_positive():
    st = ewma.init_state(N)
    # negative delta stream should still produce positive labels
    visited = jnp.ones(N, bool)
    for v in [0.9, 0.5, 0.1]:
        st = ewma.update(st, visited, jnp.full(N, v))
    lab = ewma.labels(st)
    assert bool(jnp.all(lab > 0))


# ---------------------------------------------------------------------------
# rank semantics (§3.1)
# ---------------------------------------------------------------------------

def test_count_score_is_relative_to_max():
    s = rank.query_scores("count", np.array([2, 4, 0]), np.zeros(3),
                          np.zeros(3))
    np.testing.assert_allclose(s, [0.5, 1.0, 0.0])


def test_binary_score_saturates():
    s = rank.query_scores("binary", np.array([5, 1, 0]), np.zeros(3),
                          np.zeros(3))
    np.testing.assert_allclose(s, [1.0, 1.0, 0.0])


def test_detect_score_prefers_area_at_equal_count():
    s = rank.query_scores("detect", np.array([2, 2]),
                          np.array([0.1, 0.3]), np.zeros(2))
    assert s[1] > s[0]


def test_agg_count_favors_unexplored():
    s = rank.query_scores("agg_count", np.array([3, 3]), np.zeros(2),
                          np.array([0.0, 50.0]))
    assert s[0] > s[1]          # same count, less-visited wins


def test_workload_prediction_averages_queries():
    wl = Workload((Query("yolov4", "person", "count"),
                   Query("ssd", "car", "binary")))
    counts = {("yolov4", "person"): np.array([2.0, 4.0]),
              ("ssd", "car"): np.array([0.0, 1.0])}
    areas = {k: np.zeros(2) for k in counts}
    pred = rank.predict_workload_accuracy(wl, counts, areas, np.zeros(2))
    np.testing.assert_allclose(pred, [(0.5 + 0.0) / 2, (1.0 + 1.0) / 2])


# ---------------------------------------------------------------------------
# zoom controller (§3.3)
# ---------------------------------------------------------------------------

def test_zoom_in_on_tight_cluster():
    cfg = zoom_mod.ZoomConfig()
    st = zoom_mod.ZoomState.create(N)
    cell = 12
    center = GRID.centers[cell]
    centers = center + np.array([[0.5, 0.5], [-0.5, -0.5]])
    sizes = np.full((2, 2), 1.0)
    z = zoom_mod.select_zoom(GRID, cfg, st, cell, centers, sizes, dt=1 / 15)
    assert z > 0


def test_zoom_out_when_empty():
    cfg = zoom_mod.ZoomConfig()
    st = zoom_mod.ZoomState.create(N)
    z = zoom_mod.select_zoom(GRID, cfg, st, 12, np.zeros((0, 2)),
                             np.zeros((0, 2)), dt=1 / 15)
    assert z == 0


def test_zoom_auto_out_after_3s():
    cfg = zoom_mod.ZoomConfig(zoom_out_after=3.0)
    st = zoom_mod.ZoomState.create(N)
    st.zoom_idx[12] = 2
    st.zoomed_since[12] = 2.95
    center = GRID.centers[12]
    z = zoom_mod.select_zoom(GRID, cfg, st, 12,
                             center[None] + 0.1, np.full((1, 2), 0.5),
                             dt=0.1)
    assert z == 0               # timer expired despite tight cluster


# ---------------------------------------------------------------------------
# continual replay balancing (§3.2)
# ---------------------------------------------------------------------------

def test_balanced_counts_pads_neighbors():
    window = np.zeros(N, int)
    window[12] = 10             # only the latest cell has fresh samples
    t = balanced_counts(window, 12, GRID, pad_hops=3, decay=0.5)
    hops = GRID.hop_distance[12]
    assert t[12] == 10
    assert np.all(t[hops <= 3] == 10)          # padded to max
    far = t[hops == 4]
    if far.size:
        assert np.all(far == 5)                # 10 * 0.5^1


def test_balanced_counts_empty_window():
    t = balanced_counts(np.zeros(N, int), 0, GRID)
    assert np.all(t == 0)


def test_replay_buffer_caps_capacity():
    buf = ReplayBuffer(n_cells=N, capacity_per_cell=4)
    for i in range(10):
        buf.add(3, f"s{i}")
    assert buf.count(3) == 4
    assert buf.recent(3, 2) == ["s8", "s9"]
