"""Scene simulator: determinism, dynamics statistics, rendering."""
import numpy as np
import pytest

from repro.core import DEFAULT_GRID
from repro.data import Scene, SceneConfig, build_video, gt_boxes, render_image
from repro.data.render import boxes_to_scene

GRID = DEFAULT_GRID


def test_scene_is_deterministic():
    a = Scene(SceneConfig(seed=5))
    b = Scene(SceneConfig(seed=5))
    for _ in range(30):
        a.step()
        b.step()
    np.testing.assert_array_equal(a.pos, b.pos)
    np.testing.assert_array_equal(a.oid, b.oid)


def test_objects_stay_in_bounds():
    s = Scene(SceneConfig(seed=2))
    for _ in range(200):
        s.step()
        people = s.pos[s.kind == 0]
        assert np.all(people[:, 0] >= -1) and np.all(people[:, 0] <= 151)
        assert np.all(people[:, 1] >= -1) and np.all(people[:, 1] <= 76)


def test_cars_respawn_with_new_ids():
    s = Scene(SceneConfig(seed=3, n_people=0, n_cars=6, car_speed=40.0))
    ids0 = set(s.oid.tolist())
    for _ in range(300):
        s.step()
    assert set(s.oid.tolist()) != ids0      # at least one car cycled


def test_gt_boxes_normalized():
    s = Scene(SceneConfig(seed=1))
    for _ in range(10):
        s.step()
    snap = s.snapshot()
    for cell in range(GRID.n_cells):
        for z in (1.0, 2.0, 3.0):
            gt = gt_boxes(snap, GRID, cell, z)
            if len(gt["boxes"]):
                assert gt["boxes"].min() >= -1e-6
                assert gt["boxes"].max() <= 1 + 1e-6


def test_zoom_scales_apparent_size():
    s = Scene(SceneConfig(seed=4))
    for _ in range(20):
        s.step()
    snap = s.snapshot()
    found = 0
    for cell in range(GRID.n_cells):
        g1 = gt_boxes(snap, GRID, cell, 1.0)
        g2 = gt_boxes(snap, GRID, cell, 2.0)
        common = set(g1["ids"].tolist()) & set(g2["ids"].tolist())
        for oid in common:
            i1 = g1["ids"].tolist().index(oid)
            i2 = g2["ids"].tolist().index(oid)
            # fully-visible objects: apparent size ~doubles at zoom 2
            if g1["visibility"][i1] > 0.99 and g2["visibility"][i2] > 0.99:
                ratio = g2["apparent"][i2] / g1["apparent"][i1]
                assert 1.8 < ratio < 2.2
                found += 1
    assert found > 0


def test_boxes_to_scene_inverts_gt():
    s = Scene(SceneConfig(seed=6))
    for _ in range(15):
        s.step()
    snap = s.snapshot()
    for cell in [6, 12, 18]:
        gt = gt_boxes(snap, GRID, cell, 1.0)
        if not len(gt["boxes"]):
            continue
        centers, sizes = boxes_to_scene(gt["boxes"], GRID, cell, 1.0)
        # recovered scene centers must sit inside the cell's FOV
        x0, y0 = GRID.centers[cell] - np.array(GRID.fov(1.0)) / 2
        fw, fh = GRID.fov(1.0)
        assert np.all(centers[:, 0] >= x0 - 1e-6)
        assert np.all(centers[:, 0] <= x0 + fw + 1e-6)


def test_render_image_shows_objects():
    s = Scene(SceneConfig(seed=1))
    for _ in range(20):
        s.step()
    snap = s.snapshot()
    # find a populated cell
    for cell in range(GRID.n_cells):
        gt = gt_boxes(snap, GRID, cell, 1.0)
        if len(gt["boxes"]) > 0:
            img = render_image(snap, GRID, cell, 1.0, res=64)
            assert img.shape == (64, 64, 3)
            assert img.min() >= 0 and img.max() <= 1
            return
    pytest.fail("no populated cell found")


def test_video_statistics_match_paper_regime():
    """Figures 3/9: best orientation dwell is seconds-scale and shifts are
    spatially local (median <= 2 hops)."""
    from repro.serving import detection_tables, workload_acc_table
    from repro.core import Query, Workload
    video = build_video(GRID, SceneConfig(fps=15, seed=11), duration_s=30.0)
    wl = Workload((Query("yolov4", "person", "count"),))
    tables = detection_tables(video, wl)
    acc = workload_acc_table(video, wl, tables)
    best = acc.max(-1).argmax(-1)                  # [T] best cell
    # dwell lengths
    dwells, run = [], 1
    for t in range(1, len(best)):
        if best[t] == best[t - 1]:
            run += 1
        else:
            dwells.append(run)
            run = 1
    assert len(dwells) > 3, "best orientation never changes — too static"
    # spatial locality of switches
    hops = [GRID.hop_distance[best[t - 1], best[t]]
            for t in range(1, len(best)) if best[t] != best[t - 1]]
    assert np.median(hops) <= 2.5
