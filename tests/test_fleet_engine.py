"""Fleet-scale serving: vmapped EWMA state + batched inference engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import detector as det
from repro.serving.engine import (
    InferenceEngine,
    fleet_labels,
    fleet_topk_cells,
    fleet_update_labels,
    init_fleet_state,
)

KEY = jax.random.PRNGKey(0)


def test_fleet_state_shapes():
    st = init_fleet_state(64, 25)
    assert st.acc.shape == (64, 25)


def test_fleet_update_is_per_camera():
    C, N = 8, 25
    st = init_fleet_state(C, N)
    visited = jnp.zeros((C, N), bool).at[3, 7].set(True)
    vals = jnp.zeros((C, N)).at[3, 7].set(0.9)
    st = fleet_update_labels(st, visited, vals)
    assert float(st.acc[3, 7]) == np.float32(0.9)
    assert float(st.acc[2, 7]) == 0.0          # other cameras untouched
    lab = fleet_labels(st)
    assert lab.shape == (C, N)
    vals_k, cells_k = fleet_topk_cells(lab, 4)
    assert cells_k.shape == (C, 4)
    assert int(cells_k[3, 0]) == 7             # camera 3's best is cell 7


def test_fleet_scales_without_recompile():
    """The same jitted update handles any fleet width via vmap tracing
    once per shape — 1k cameras is just a bigger leading axis."""
    st = init_fleet_state(1000, 25)
    visited = jnp.zeros((1000, 25), bool).at[:, 0].set(True)
    vals = jnp.full((1000, 25), 0.5)
    st = fleet_update_labels(st, visited, vals)
    assert float(st.acc[999, 0]) == 0.5


def test_engine_batch_scoring():
    cfg = get_smoke_config("madeye-approx")
    params = det.detector_init(KEY, cfg)
    engine = InferenceEngine(cfg, params)
    imgs = jax.random.uniform(KEY, (6, cfg.img_res, cfg.img_res, 3))
    d = engine.score_batch(imgs)
    assert d.boxes.shape == (6, cfg.max_boxes, 4)
    counts, areas = engine.counts_and_areas(imgs, score_thresh=0.0)
    assert counts.shape == (6,)
    assert bool(jnp.all(counts == cfg.max_boxes))   # thresh 0 keeps all


def test_serve_rules_are_valid(monkeypatch):
    """REPRO_SERVE_TP_ONLY / REPRO_SERVE_REPLICATED produce coherent spec
    trees for a real model."""
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_abstract_mesh
    from repro.models.transformer import lm_init
    cfg = get_smoke_config("stablelm-3b")
    p_shape = jax.eval_shape(lambda k: lm_init(k, cfg),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh = make_abstract_mesh((16, 16), ("data", "model"))

    monkeypatch.setenv("REPRO_SERVE_TP_ONLY", "1")
    sh = shd.param_shardings(p_shape, mesh)
    # TP-only: no weight carries a 'data' axis
    for leaf in jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")):
        for s in leaf.spec:
            assert s != ("data",) and s != "data"

    monkeypatch.setenv("REPRO_SERVE_REPLICATED", "1")
    sh2 = shd.param_shardings(p_shape, mesh)
    for leaf in jax.tree.leaves(sh2, is_leaf=lambda x: hasattr(x, "spec")):
        assert all(s is None for s in leaf.spec)


def test_fleet_step_end_to_end():
    """Fleet-wide rank+EWMA+select in one jitted call."""
    from repro.serving.engine import fleet_step
    C, N = 16, 25
    st = init_fleet_state(C, N)
    rng = np.random.default_rng(0)
    visited = jnp.asarray(rng.random((C, N)) < 0.3)
    counts = jnp.asarray(
        rng.poisson(2.0, (C, N)).astype(np.float32)) * visited
    areas = counts * 0.01
    st2, cells, pred = fleet_step(st, counts, areas, visited, k_send=2)
    assert cells.shape == (C, 2)
    # the top pick per camera is its max-count explored cell
    for c in range(C):
        vis = np.flatnonzero(np.asarray(visited[c]))
        if vis.size and float(counts[c].max()) > 0:
            best = vis[np.argmax(np.asarray(counts[c])[vis])]
            assert float(pred[c, int(cells[c, 0])]) >= \
                float(pred[c, best]) - 1e-6
    # EWMA advanced exactly on visited cells
    assert bool(jnp.all((np.asarray(st2.seen) > 0) == np.asarray(visited)))


def test_fleet_step_scales_to_10k_cameras():
    from repro.serving.engine import fleet_step
    import time
    C, N = 10_000, 25
    st = init_fleet_state(C, N)
    visited = jnp.ones((C, N), bool)
    counts = jnp.abs(jax.random.normal(KEY, (C, N)))
    st2, cells, _ = fleet_step(st, counts, counts * 0.01, visited)
    cells.block_until_ready()
    t0 = time.perf_counter()
    st2, cells, _ = fleet_step(st, counts, counts * 0.01, visited)
    cells.block_until_ready()
    dt = time.perf_counter() - t0
    assert cells.shape == (C, 2)
    assert dt < 1.0, f"fleet step too slow: {dt:.3f}s for 10k cameras"
