"""Unified fleet experiment API (repro.fleet.api).

Pins the declarative surface: FleetRunSpec/FleetResult JSON round trips,
provider-registry dispatch (unknown names fail loudly, custom providers
plug in), ShardSpec mesh resolution through the public API, and the
detector checkpoint path (.npz round trip + trained-vs-demo threshold
defaults).
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.core import DEFAULT_GRID, Workload
from repro.core.tradeoff import BudgetConfig
from repro.fleet import (
    DEFAULT_QUERIES,
    FleetResult,
    FleetRunSpec,
    ObservationProvider,
    SceneProvider,
    ShardSpec,
    available_providers,
    fleet_config,
    load_detector_params,
    make_detector_provider,
    make_scene_provider,
    prepare_fleet_run,
    provider_factory,
    register_provider,
    run_fleet,
    save_detector_params,
)
from repro.fleet import api as api_mod

GRID = DEFAULT_GRID
BUDGET = BudgetConfig(fps=2.0)


# ---------------------------------------------------------------------------
# spec round trip + object views
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    spec = FleetRunSpec(
        provider="detector", n_cameras=3, n_steps=7, seed=5,
        budget={"fps": 2.0, "max_send": 3}, grid={"pan_step": 30.0},
        provider_kwargs={"scene_seeds": [1, 2, 3], "noise": 0.1},
        shard=ShardSpec(kind="debug", n_data=1))
    s = spec.to_json()
    spec2 = FleetRunSpec.from_json(s)
    assert spec2 == spec
    assert spec2.to_json() == s
    assert isinstance(spec2.shard, ShardSpec)
    # numpy-valued provider kwargs serialize as lists
    spec3 = dataclasses.replace(
        spec, provider_kwargs={"scene_seeds": np.arange(3)})
    spec4 = FleetRunSpec.from_json(spec3.to_json())
    assert spec4.provider_kwargs["scene_seeds"] == [0, 1, 2]


def test_spec_distill_json_roundtrip():
    """DistillSpec normalizes like metrics (True/False/dict) and
    round-trips through spec JSON as a plain dict."""
    from repro.learn import DistillSpec

    assert FleetRunSpec(distill=None).distill is None
    assert FleetRunSpec(distill=False).distill is None
    assert FleetRunSpec(distill={"enabled": False}).distill is None
    assert FleetRunSpec(distill=True).distill == DistillSpec()
    spec = FleetRunSpec(provider="detector", distill={
        "optimizer": "sgd", "lr": 0.05, "schedule": "cosine",
        "every": 2, "buffer": 4})
    assert spec.distill == DistillSpec(
        optimizer="sgd", lr=0.05, schedule="cosine", every=2, buffer=4)
    spec2 = FleetRunSpec.from_json(spec.to_json())
    assert spec2 == spec and isinstance(spec2.distill, DistillSpec)
    assert spec2.to_json() == spec.to_json()


def test_spec_object_views():
    spec = FleetRunSpec(budget={"fps": 2.0})
    assert spec.grid_obj() == DEFAULT_GRID
    assert spec.budget_obj() == BudgetConfig(fps=2.0)
    wl = spec.workload_obj()
    assert isinstance(wl, Workload)
    assert tuple((q.model, q.obj, q.task) for q in wl.queries) \
        == DEFAULT_QUERIES
    # from_objects inverts the views
    spec2 = FleetRunSpec.from_objects(
        "scene", n_cameras=4, n_steps=8, grid=GRID, workload=wl,
        budget=BudgetConfig(fps=2.0), churn=0.0)
    assert spec2.workload == DEFAULT_QUERIES
    assert spec2.budget_obj() == BudgetConfig(fps=2.0)
    assert spec2.grid_obj() == GRID
    assert spec2.provider_kwargs == {"churn": 0.0}


# ---------------------------------------------------------------------------
# registry dispatch
# ---------------------------------------------------------------------------

def test_registry_unknown_provider_lists_available():
    with pytest.raises(KeyError) as ei:
        provider_factory("warp-drive")
    msg = str(ei.value)
    for name in ("detector", "scene", "tables"):
        assert name in msg
    with pytest.raises(KeyError):
        run_fleet(FleetRunSpec(provider="warp-drive"))


def test_registry_accepts_custom_provider():
    seen = {}

    def factory(grid, workload, cfg, *, n_cameras, n_steps, seed, **kw):
        seen["call"] = (n_cameras, n_steps, seed, kw)
        return make_scene_provider(grid, workload, cfg,
                                   n_cameras=n_cameras, n_steps=n_steps,
                                   seed=seed, **kw)

    register_provider("my-scene", factory)
    try:
        assert "my-scene" in available_providers()
        prep = prepare_fleet_run(FleetRunSpec(
            provider="my-scene", n_cameras=2, n_steps=3, seed=9,
            provider_kwargs={"churn": 0.0}))
        assert isinstance(prep.provider, SceneProvider)
        assert isinstance(prep.provider, ObservationProvider)
        assert seen["call"] == (2, 3, 9, {"churn": 0.0})
    finally:
        del api_mod._PROVIDERS["my-scene"]


# ---------------------------------------------------------------------------
# run_fleet end to end + result round trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_result():
    return run_fleet(FleetRunSpec(
        provider="scene", n_cameras=2, n_steps=4, budget={"fps": 2.0},
        provider_kwargs={"scene_seeds": [3, 3]}))


def test_run_fleet_result_fields(tiny_result):
    r = tiny_result
    assert (r.n_cameras, r.n_steps) == (2, 4)
    assert len(r.acc_per_step) == 4
    assert len(r.chosen) == 4 and len(r.chosen[0]) == 2
    assert len(r.frames_sent) == 4
    assert 0.0 <= r.accuracy <= 1.0
    assert r.accuracy == pytest.approx(
        float(np.mean(r.acc_per_step)), abs=1e-6)
    # identically-seeded cameras choose in lockstep
    chosen = np.asarray(r.chosen)
    np.testing.assert_array_equal(chosen[:, 0], chosen[:, 1])
    assert r.state is not None and r.out is not None
    assert r.out.explored.shape[:2] == (4, 2)
    assert r.timings["episode_s"] > 0 and r.camera_steps_per_s > 0


def test_result_json_roundtrip(tiny_result):
    s = tiny_result.to_json()
    r2 = FleetResult.from_json(s)
    assert r2.state is None and r2.out is None
    assert r2.to_json() == s
    assert r2.accuracy == pytest.approx(tiny_result.accuracy)
    assert r2.spec == tiny_result.spec
    assert r2.chosen == tiny_result.chosen


# ---------------------------------------------------------------------------
# ShardSpec through the public API
# ---------------------------------------------------------------------------

def test_shard_spec_resolution():
    assert ShardSpec().build_mesh() is None
    with pytest.raises(ValueError):
        ShardSpec(kind="warp").build_mesh()
    mesh = ShardSpec(kind="debug").build_mesh()
    assert mesh.axis_names == ("data", "model")


def test_run_fleet_sharded_matches_unsharded(tiny_result):
    sharded = run_fleet(FleetRunSpec(
        provider="scene", n_cameras=2, n_steps=4, budget={"fps": 2.0},
        provider_kwargs={"scene_seeds": [3, 3]},
        shard=ShardSpec(kind="debug")))
    assert sharded.chosen == tiny_result.chosen
    assert sharded.frames_sent == tiny_result.frames_sent


# ---------------------------------------------------------------------------
# detector checkpoints (.npz) + threshold defaults
# ---------------------------------------------------------------------------

def test_detector_params_npz_roundtrip(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models.detector import detector_init

    det_cfg = get_smoke_config("madeye-approx")
    params = detector_init(jax.random.PRNGKey(7), det_cfg)
    path = save_detector_params(str(tmp_path / "det.npz"), params)
    loaded = load_detector_params(path)
    assert jax.tree.structure(loaded) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(loaded), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    cfg = fleet_config(GRID, BUDGET)
    wl = FleetRunSpec().workload_obj()
    # undistilled demo net: thresholds sit inside a fresh net's score
    # range; a trained checkpoint (pytree OR path) gets the 0.5 default
    fresh, _ = make_detector_provider(GRID, wl, cfg, n_cameras=1,
                                      n_steps=2)
    assert float(fresh.thresh[0]) == pytest.approx(0.3)
    from_path, _ = make_detector_provider(GRID, wl, cfg, n_cameras=1,
                                          n_steps=2, det_params=path)
    assert float(from_path.thresh[0]) == pytest.approx(0.5)
    assert float(from_path.geo_thresh) == pytest.approx(0.55)
    from_tree, _ = make_detector_provider(GRID, wl, cfg, n_cameras=1,
                                          n_steps=2, det_params=params)
    for a, b in zip(jax.tree.leaves(from_path.det_params),
                    jax.tree.leaves(from_tree.det_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_detector_params_rejects_non_contract(tmp_path):
    """Anything outside 'nested dicts of arrays with clean keys' fails
    at save time instead of loading back as a different treedef."""
    bad = str(tmp_path / "bad.npz")
    with pytest.raises(TypeError):
        save_detector_params(bad, np.zeros(3))          # non-dict root
    with pytest.raises(ValueError):
        save_detector_params(bad, {"a/b": np.zeros(3)})  # '/' in key
    with pytest.raises(TypeError):
        save_detector_params(bad, {"a": [1, 2, 3]})      # non-array leaf


# ---------------------------------------------------------------------------
# detector provider knobs: chunk slabs + candidate shortlist
# ---------------------------------------------------------------------------

def _detector_provider(**kw):
    cfg = fleet_config(GRID, BUDGET)
    wl = FleetRunSpec().workload_obj()
    return make_detector_provider(GRID, wl, cfg, n_cameras=1, n_steps=2,
                                  **kw)[0]


def test_auto_chunk_selection():
    """Default chunk = one cell-row of zooms when it divides N*Z; on
    window counts where it doesn't, the largest divisor <= the default
    is chosen instead of silently slabbing unevenly."""
    from repro.fleet.runner import _auto_chunk

    assert _detector_provider().chunk == 15     # 5x5 grid, 3 zooms
    assert _detector_provider(chunk=25).chunk == 25
    assert _auto_chunk(75, 15) == 15
    assert _auto_chunk(21, 6) == 3              # odd: walk down to 3
    assert _auto_chunk(13, 6) == 1              # prime window count
    assert _auto_chunk(30, 100) == 30           # default > n_windows
    for c in (75, 21, 13, 8):
        for default in (1, 5, 6, 15, 100):
            got = _auto_chunk(c, default)
            assert c % got == 0 and 1 <= got <= max(1, min(default, c))


def test_non_dividing_chunk_fails_loudly():
    with pytest.raises(ValueError, match="must divide"):
        _detector_provider(chunk=7)             # 75 % 7 != 0


def test_shortlist_k_validation():
    """shortlist_k keeps whole cells (multiples of the zoom count) and
    is bounded by N*Z; the chunked reference path is exhaustive-only."""
    assert _detector_provider().shortlist_k == 75          # default: all
    assert _detector_provider(shortlist_k=18).shortlist_k == 18
    with pytest.raises(ValueError, match="multiple of the"):
        _detector_provider(shortlist_k=10)                 # 10 % 3 != 0
    with pytest.raises(ValueError, match="multiple of the"):
        _detector_provider(shortlist_k=78)                 # > N*Z
    with pytest.raises(ValueError, match="multiple of the"):
        _detector_provider(shortlist_k=0)
    with pytest.raises(ValueError, match="exhaustive"):
        _detector_provider(shortlist_k=18, fused=False)
    assert not _detector_provider(fused=False).fused       # ok: all cells
    # un-shortlisted windows scatter as score-0 detections, which only
    # read as empty under a strictly positive threshold
    with pytest.raises(ValueError, match="positive thresh"):
        _detector_provider(shortlist_k=18, thresh=0.0)
    assert _detector_provider(thresh=0.0).shortlist_k == 75  # exhaustive ok


def test_spec_shortlist_k_field_plumbs_and_roundtrips():
    """The first-class FleetRunSpec.shortlist_k reaches the detector
    factory and survives the JSON round trip."""
    spec = FleetRunSpec(provider="detector", n_cameras=1, n_steps=2,
                        budget={"fps": 2.0}, shortlist_k=18)
    assert FleetRunSpec.from_json(spec.to_json()) == spec
    prep = prepare_fleet_run(spec)
    assert prep.provider.shortlist_k == 18
    # default None leaves the provider exhaustive
    prep = prepare_fleet_run(dataclasses.replace(spec, shortlist_k=None))
    assert prep.provider.shortlist_k == 75
    # providers without a per-window model reject it loudly
    with pytest.raises(TypeError):
        prepare_fleet_run(FleetRunSpec(provider="scene", n_cameras=1,
                                       n_steps=2, shortlist_k=18))
