"""Hypothesis property tests on MadEye's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import search
from repro.core.grid import (
    DEFAULT_GRID,
    contiguous,
    removal_keeps_contiguity,
)
from repro.core.path import planner_for, prim_mst

GRID = DEFAULT_GRID
N = GRID.n_cells


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@st.composite
def contiguous_masks(draw, grid=GRID, max_size=10):
    """Random contiguous shapes grown from a seed cell."""
    size = draw(st.integers(1, max_size))
    start = draw(st.integers(0, grid.n_cells - 1))
    mask = np.zeros(grid.n_cells, bool)
    mask[start] = True
    for _ in range(size - 1):
        frontier = np.flatnonzero(
            ~mask & (grid.neighbor_mask[mask].any(0)))
        if frontier.size == 0:
            break
        mask[draw(st.sampled_from(list(map(int, frontier))))] = True
    return mask


labels_arrays = st.lists(
    st.floats(0.001, 1.0), min_size=N, max_size=N).map(np.asarray)


# ---------------------------------------------------------------------------
# grid / contiguity
# ---------------------------------------------------------------------------

@given(contiguous_masks())
@settings(max_examples=30, deadline=None)
def test_generated_masks_are_contiguous(mask):
    assert contiguous(mask, GRID)


@given(contiguous_masks(), st.integers(0, N - 1))
@settings(max_examples=30, deadline=None)
def test_removal_check_is_sound(mask, cell):
    """If removal_keeps_contiguity says yes, the result IS contiguous."""
    if not mask[cell]:
        return
    if removal_keeps_contiguity(mask, cell, GRID):
        m = mask.copy()
        m[cell] = False
        assert contiguous(m, GRID)


def test_grid_geometry():
    assert GRID.n_cells == 25 and GRID.n_orientations == 75
    d = GRID.angular_distance
    assert np.allclose(d, d.T) and np.all(np.diag(d) == 0)
    # triangle inequality (required by the TSP 2-approx)
    for i in range(N):
        for j in range(N):
            for k in range(0, N, 7):
                assert d[i, j] <= d[i, k] + d[k, j] + 1e-9


# ---------------------------------------------------------------------------
# search invariants
# ---------------------------------------------------------------------------

@given(contiguous_masks(), labels_arrays)
@settings(max_examples=30, deadline=None)
def test_evolve_preserves_contiguity_and_size(mask, labels):
    centroids = GRID.centers.copy()
    has_boxes = np.ones(N, bool)
    out = search.evolve_shape(GRID, mask, labels, centroids, has_boxes)
    assert contiguous(out, GRID)
    assert out.sum() == mask.sum()          # evolve swaps, never resizes


@given(contiguous_masks(), labels_arrays, st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_resize_hits_target_and_stays_contiguous(mask, labels, target):
    centroids = GRID.centers.copy()
    has_boxes = np.zeros(N, bool)
    out = search.resize_shape(GRID, mask, labels, centroids, has_boxes,
                              target)
    assert contiguous(out, GRID)
    assert out.sum() == target


@given(st.integers(1, 25), st.integers(0, N - 1))
@settings(max_examples=30, deadline=None)
def test_seed_shape_contiguous_and_bounded(size, center):
    mask = search.seed_shape(GRID, size, center)
    assert contiguous(mask, GRID)
    assert 1 <= mask.sum() <= size


# ---------------------------------------------------------------------------
# path planner
# ---------------------------------------------------------------------------

def test_mst_is_spanning_tree():
    edges = prim_mst(GRID.angular_distance)
    assert len(edges) == N - 1
    # connectivity via union-find
    parent = list(range(N))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        parent[find(a)] = find(b)
    assert len({find(i) for i in range(N)}) == 1


@given(contiguous_masks(max_size=12), st.integers(0, N - 1))
@settings(max_examples=30, deadline=None)
def test_walk_visits_every_cell_exactly_once(mask, start):
    planner = planner_for(GRID)
    order = planner.subtree_walk(mask, start)
    assert sorted(order) == sorted(np.flatnonzero(mask).tolist())


@given(contiguous_masks(max_size=12), st.integers(0, N - 1))
@settings(max_examples=30, deadline=None)
def test_walk_within_2x_optimal_mst_bound(mask, start):
    """Preorder walk length <= 2 * MST weight of the shape (the classic
    2-approximation guarantee)."""
    planner = planner_for(GRID)
    cells = np.flatnonzero(mask)
    if cells.size < 2:
        return
    order = planner.subtree_walk(mask, start)
    walk = planner.path_time(order, rotation_speed=1.0)
    sub = GRID.angular_distance[np.ix_(cells, cells)]
    mst_w = sum(sub[a, b] for a, b in prim_mst(sub))
    start_cost = GRID.angular_distance[start][cells].min()
    assert walk <= 2 * mst_w + start_cost + 1e-6


@given(contiguous_masks(max_size=10), labels_arrays, st.integers(0, N - 1))
@settings(max_examples=20, deadline=None)
def test_shrink_to_budget_feasible_result(mask, labels, start):
    planner = planner_for(GRID)
    budget = 0.05
    cells, order, t = planner.shrink_to_budget(
        mask, start, labels, rotation_speed=400.0, time_budget=budget,
        per_cell_cost=0.005)
    assert cells.sum() >= 1
    if cells.sum() > 1:
        assert t <= budget + 1e-9
    assert contiguous(cells, GRID)


# ---------------------------------------------------------------------------
# tradeoff coherence
# ---------------------------------------------------------------------------

@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.sampled_from([1.0, 5.0, 15.0, 30.0]))
@settings(max_examples=40, deadline=None)
def test_plan_is_coherent(train_acc, var, fps):
    from repro.core.tradeoff import BudgetConfig, NetworkEstimator, \
        plan_timestep
    net = NetworkEstimator()
    net.observe(24.0, 0.02)
    cfg = BudgetConfig(fps=fps)
    k, t_explore, max_cells = plan_timestep(train_acc, var, net, cfg)
    assert cfg.min_send <= k <= cfg.max_send
    assert max_cells >= 1
    assert t_explore >= 0
    # coherence: we never plan to send more frames than cells we explore
    assert k <= max_cells
