"""Device-resident scene substrate: determinism, conservation, statistics.

The JAX scene is a new substrate, not a bit-replay of data/scene.py — so
these tests pin what actually matters: streams are reproducible and
independent of fleet size/shard layout (the per-camera fold_in key
discipline), object count is conserved between spawn events (fixed-shape
respawn keeps density stationary, ids stay unique), and the emergent
best-orientation statistics the paper's design leans on (dwell time,
1-hop accuracy-delta correlation) match the numpy simulator within
tolerance when both are measured through the same gt_boxes oracle.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import DEFAULT_GRID
from repro.data.render import gt_boxes
from repro.data.scene import Scene, SceneConfig
from repro.scene_jax import (
    SceneSpec,
    advance_scene,
    fleet_from_config,
    init_scene,
    scene_fleet_params,
    scene_step,
)

GRID = DEFAULT_GRID


def _rollout(spec, params, rng, n_frames):
    """Scan the fleet scene n_frames forward; returns stacked [T, F, ...]
    (pos, size, oid) device arrays."""
    st = init_scene(spec, params, rng)

    def body(sc, t):
        keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(rng, t)
        sc = scene_step(spec, params, keys, sc)
        return sc, (sc.pos, sc.size, sc.oid)

    _, ys = jax.lax.scan(body, st, jnp.arange(n_frames))
    return ys


# ---------------------------------------------------------------------------
# determinism / fleet-size independence (the FleetState.rng contract)
# ---------------------------------------------------------------------------

def test_same_scene_seed_is_deterministic():
    spec = SceneSpec()
    params, rng = scene_fleet_params(spec, 2, scene_seeds=[5, 5])
    pos, size, oid = _rollout(spec, params, rng, 30)
    np.testing.assert_array_equal(np.asarray(pos[:, 0]),
                                  np.asarray(pos[:, 1]))
    np.testing.assert_array_equal(np.asarray(oid[:, 0]),
                                  np.asarray(oid[:, 1]))


def test_stream_independent_of_fleet_size():
    """Camera seed 7 sees the identical world whether it rides in an F=1
    or an F=3 fleet (and regardless of its lane) — keys derive from the
    camera's seed, never from the fleet layout."""
    spec = SceneSpec()
    p1, r1 = scene_fleet_params(spec, 1, scene_seeds=[7])
    p3, r3 = scene_fleet_params(spec, 3, scene_seeds=[2, 7, 11])
    pos1, _, oid1 = _rollout(spec, p1, r1, 25)
    pos3, _, oid3 = _rollout(spec, p3, r3, 25)
    np.testing.assert_allclose(np.asarray(pos1[:, 0]),
                               np.asarray(pos3[:, 1]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(oid1[:, 0]),
                                  np.asarray(oid3[:, 1]))


def test_different_seeds_diverge():
    spec = SceneSpec()
    params, rng = scene_fleet_params(spec, 2, scene_seeds=[1, 2])
    pos, _, _ = _rollout(spec, params, rng, 10)
    assert not np.allclose(np.asarray(pos[:, 0]), np.asarray(pos[:, 1]))


# ---------------------------------------------------------------------------
# conservation / spawn properties
# ---------------------------------------------------------------------------

def test_object_count_conserved_and_ids_unique():
    """Respawn replaces objects in place: the live-slot count never
    changes, sizes stay positive for enabled slots, and ids never collide
    within a camera."""
    spec = SceneSpec()
    params, rng = scene_fleet_params(spec, 3, scene_seeds=[0, 1, 2],
                                     n_people=[14, 8, 4],
                                     n_cars=[8, 4, 2],
                                     car_speed=30.0, churn=0.05)
    enabled = np.asarray(params.enabled)
    pos, size, oid = (np.asarray(x)
                      for x in _rollout(spec, params, rng, 120))
    for f in range(3):
        live = (size[:, f, :, 0] > 0) & (size[:, f, :, 1] > 0)
        # enabled slots stay live every frame; disabled never appear
        assert (live == enabled[f][None, :]).all(), f"camera {f}"
        for t in range(0, 120, 17):
            ids = oid[t, f][enabled[f]]
            assert len(set(ids.tolist())) == len(ids), f"id collision {f}"


def test_cars_respawn_with_new_ids():
    spec = SceneSpec(max_people=0, max_cars=6)
    params, rng = scene_fleet_params(spec, 1, scene_seeds=[3],
                                     car_speed=40.0)
    _, _, oid = _rollout(spec, params, rng, 300)
    oid = np.asarray(oid)[:, 0]
    assert set(oid[-1].tolist()) != set(oid[0].tolist())
    assert oid.max() > spec.max_objects        # fresh ids were issued


def test_people_stay_in_bounds():
    spec = SceneSpec(max_cars=0)
    params, rng = scene_fleet_params(spec, 2, scene_seeds=[2, 9],
                                     person_speed=2.5)
    pos, _, _ = _rollout(spec, params, rng, 200)
    pos = np.asarray(pos)
    assert pos[..., 0].min() >= -1 and pos[..., 0].max() <= 151
    assert pos[..., 1].min() >= -1 and pos[..., 1].max() <= 76


def test_advance_scene_strides_frames():
    """advance_scene(step e, stride s) == s raw frames at indices
    e*s .. e*s+s-1 — the materialized-tables replay contract."""
    spec = SceneSpec()
    params, rng = scene_fleet_params(spec, 1, scene_seeds=[4])
    st = init_scene(spec, params, rng)
    a = advance_scene(spec, params, rng, st,
                      jnp.zeros(1, jnp.int32), 5)
    b = st
    for t in range(5):
        keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(rng, t)
        b = scene_step(spec, params, keys, b)
    np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.oid), np.asarray(b.oid))


# ---------------------------------------------------------------------------
# emergent statistics vs the numpy simulator (same gt_boxes oracle)
# ---------------------------------------------------------------------------

def _cell_count_table(frames_pos, frames_size, n_frames):
    """[T, N] exact object count per cell at zoom 1 via gt_boxes."""
    out = np.zeros((n_frames, GRID.n_cells))
    for t in range(n_frames):
        snap = {"pos": frames_pos[t], "size": frames_size[t],
                "kind": np.zeros(len(frames_pos[t]), int),
                "oid": np.arange(len(frames_pos[t])), "t": t}
        for c in range(GRID.n_cells):
            out[t, c] = len(gt_boxes(snap, GRID, c, 1.0)["boxes"])
    return out


def _dwell_and_corr(counts):
    """(median best-cell dwell in frames, mean 1-hop delta correlation)."""
    best = counts.argmax(-1)
    dwells, run = [], 1
    for t in range(1, len(best)):
        if best[t] == best[t - 1]:
            run += 1
        else:
            dwells.append(run)
            run = 1
    dwells.append(run)
    deltas = np.diff(counts, axis=0)
    cors = []
    for i in range(GRID.n_cells):
        for j in range(i + 1, GRID.n_cells):
            if GRID.hop_distance[i, j] != 1:
                continue
            si, sj = deltas[:, i].std(), deltas[:, j].std()
            if si < 1e-9 or sj < 1e-9:
                continue
            cors.append(float(np.corrcoef(deltas[:, i], deltas[:, j])[0, 1]))
    return float(np.median(dwells)), float(np.mean(cors))


@pytest.mark.parametrize("seed", [3])
def test_statistics_match_numpy_scene(seed):
    T = 240                                    # 16 s at 15 fps
    # non-default dynamics so the port is exercised, not just defaults
    cfg = SceneConfig(fps=15, seed=seed, person_speed=1.5, churn=0.02)
    sc = Scene(cfg)
    np_pos, np_size = [], []
    for _ in range(T):
        sc.step()
        np_pos.append(sc.pos.copy())
        np_size.append(sc.size.copy())
    counts_np = _cell_count_table(np_pos, np_size, T)

    spec, params, rng = fleet_from_config(cfg, 1, scene_seeds=[seed])
    pos, size, _ = _rollout(spec, params, rng, T)
    counts_jx = _cell_count_table(np.asarray(pos[:, 0]),
                                  np.asarray(size[:, 0]), T)

    dwell_np, corr_np = _dwell_and_corr(counts_np)
    dwell_jx, corr_jx = _dwell_and_corr(counts_jx)
    # same dynamical regime, not the same trajectory: seconds-scale
    # dwell within a factor 4, neighbor correlation within 0.35
    assert 1 / 4 <= (dwell_jx + 1) / (dwell_np + 1) <= 4, \
        (dwell_jx, dwell_np)
    assert abs(corr_jx - corr_np) <= 0.35, (corr_jx, corr_np)
    assert corr_jx > 0.2, "neighbor cells should be positively correlated"
