"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.box_iou.ops import box_iou, match_boxes, nms_mask
from repro.kernels.box_iou.ref import box_iou_ref
from repro.kernels.cell_rasterize.ops import cell_rasterize, window_arrays
from repro.kernels.crop_patchify.ops import crop_patchify
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.frame_delta.ops import apply_delta, frame_delta
from repro.kernels.frame_delta.ref import frame_delta_ref
from repro.kernels.neighbor_score.ops import geometry_arrays, neighbor_scores
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Sk, Hq, Hkv, D, causal, dtype)
    (1, 64, 64, 2, 2, 32, False, jnp.float32),
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 100, 100, 2, 1, 24, True, jnp.float32),     # ragged + MQA
    (1, 1, 96, 4, 4, 16, False, jnp.float32),       # decode shape
    (2, 72, 136, 3, 1, 48, False, jnp.float32),     # Sq != Sk
    (1, 64, 64, 2, 2, 32, False, jnp.bfloat16),
    (1, 256, 256, 2, 2, 128, True, jnp.float32),    # full MXU tile dims
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[str(c) for c in FLASH_CASES])
def test_flash_attention_matches_ref(case):
    B, Sq, Sk, Hq, Hkv, D, causal, dtype = case
    kq, kk, kv = jax.random.split(jax.random.fold_in(KEY, hash(case) % 997), 3)
    q = jax.random.normal(kq, (B, Sq, Hq, D), dtype)
    k = jax.random.normal(kk, (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(kv, (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)

    g = Hq // Hkv
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                        causal=causal).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_q_offset():
    """Decode with a cache: q_offset shifts the causal mask."""
    q = jax.random.normal(KEY, (1, 8, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 32, 2, 16))
    out = flash_attention(q, k, v, causal=True, q_offset=24,
                          block_q=8, block_k=8)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        q_offset=24).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# box IoU + NMS + matching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(8, 8), (37, 13), (128, 256), (5, 300),
                                 (1, 1)])
def test_box_iou_matches_ref(n, m):
    ka, kb = jax.random.split(jax.random.fold_in(KEY, n * 1000 + m))
    a = jnp.abs(jax.random.normal(ka, (n, 4))) * 0.3 + 0.05
    b = jnp.abs(jax.random.normal(kb, (m, 4))) * 0.3 + 0.05
    np.testing.assert_allclose(np.asarray(box_iou(a, b)),
                               np.asarray(box_iou_ref(a, b)), atol=1e-6)


def test_iou_identity():
    boxes = jnp.abs(jax.random.normal(KEY, (16, 4))) * 0.2 + 0.1
    iou = box_iou(boxes, boxes)
    np.testing.assert_allclose(np.asarray(jnp.diag(iou)), 1.0, atol=1e-5)


def test_nms_suppresses_overlaps():
    boxes = jnp.array([[0.5, 0.5, 0.2, 0.2], [0.51, 0.5, 0.2, 0.2],
                       [0.9, 0.9, 0.1, 0.1]])
    keep = nms_mask(boxes, jnp.array([0.9, 0.8, 0.7]), jnp.ones(3, bool))
    assert bool(keep[0]) and not bool(keep[1]) and bool(keep[2])


def test_nms_respects_validity():
    boxes = jnp.array([[0.5, 0.5, 0.2, 0.2], [0.9, 0.9, 0.1, 0.1]])
    keep = nms_mask(boxes, jnp.array([0.9, 0.8]),
                    jnp.array([False, True]))
    assert not bool(keep[0]) and bool(keep[1])


def test_match_boxes_one_to_one():
    pred = jnp.array([[0.5, 0.5, 0.2, 0.2], [0.5, 0.5, 0.2, 0.2]])
    gt = jnp.array([[0.5, 0.5, 0.2, 0.2]])
    tp, m = match_boxes(pred, gt, jnp.ones(1, bool))
    # only the first (higher-ranked) pred claims the single GT
    assert bool(tp[0]) and not bool(tp[1])
    assert int(m[0]) == 0 and int(m[1]) == -1


# ---------------------------------------------------------------------------
# neighbor score (fleet shape-search inner loop)
# ---------------------------------------------------------------------------

def _neighbor_inputs(b, seed=0):
    from repro.core.grid import DEFAULT_GRID
    rng = np.random.default_rng(seed)
    n = DEFAULT_GRID.n_cells
    mask = rng.random((b, n)) < 0.3
    mask[:, 0] |= ~mask.any(1)                  # at least one member
    has = rng.random((b, n)) < 0.7
    cents = rng.uniform(0.0, [150.0, 75.0], (b, n, 2)).astype(np.float32)
    heads = np.array([rng.choice(np.flatnonzero(m)) for m in mask],
                     np.int32)
    geo = geometry_arrays(DEFAULT_GRID)
    args = (jnp.asarray(mask), jnp.asarray(has), jnp.asarray(cents),
            jnp.asarray(heads), jnp.asarray(geo["d_center"]),
            jnp.asarray(geo["overlap"]), jnp.asarray(geo["cell_x"]),
            jnp.asarray(geo["cell_y"]), jnp.asarray(geo["neighbor8"]))
    return mask, has, cents, heads, args


@pytest.mark.parametrize("b", [1, 7, 64, 130])
def test_neighbor_score_kernel_matches_ref(b):
    """Pallas kernel path (padded to lanes) == fused-jnp reference path."""
    _, _, _, _, args = _neighbor_inputs(b, seed=b)
    s_ref, cand_ref = neighbor_scores(*args, use_kernel=False)
    s_ker, cand_ker = neighbor_scores(*args, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(cand_ref),
                                  np.asarray(cand_ker))
    np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_ref),
                               atol=1e-5, rtol=1e-5)


def test_neighbor_score_matches_core_neighbor():
    """Both dispatch paths reproduce core/neighbor.score_candidates."""
    from repro.core import neighbor as nb
    from repro.core.grid import DEFAULT_GRID
    mask, has, cents, heads, args = _neighbor_inputs(16, seed=3)
    for use_kernel in (False, True):
        s, cand = neighbor_scores(*args, use_kernel=use_kernel)
        s, cand = np.asarray(s), np.asarray(cand)
        for b in range(mask.shape[0]):
            cands_np, scores_np = nb.score_candidates(
                DEFAULT_GRID, mask[b], int(heads[b]), cents[b], has[b])
            assert set(cands_np.tolist()) == \
                set(np.flatnonzero(cand[b]).tolist())
            for c, sc in zip(cands_np, scores_np):
                np.testing.assert_allclose(s[b, c], sc, atol=1e-5)


# ---------------------------------------------------------------------------
# cell rasterize (scene substrate boxes -> cells x zooms)
# ---------------------------------------------------------------------------

def _rasterize_inputs(b, m, p, seed=0):
    from repro.core.grid import DEFAULT_GRID
    rng = np.random.default_rng(seed)
    ox = rng.uniform(-10, 160, (b, m)).astype(np.float32)
    oy = rng.uniform(-10, 85, (b, m)).astype(np.float32)
    ow = rng.uniform(0, 9, (b, m)).astype(np.float32)
    oh = rng.uniform(0, 9, (b, m)).astype(np.float32)
    ow[:, -2:] = 0.0                                # "disabled" slots
    draw = rng.uniform(0, 1.2, (b, p, m)).astype(np.float32)
    draw[:, :, -1] = 2.0                            # never-detect mask
    a0 = rng.uniform(0.02, 0.1, p).astype(np.float32)
    a1 = (a0 + rng.uniform(0.05, 0.2, p)).astype(np.float32)
    win = jnp.asarray(window_arrays(DEFAULT_GRID))
    return (ox, oy, ow, oh, draw, a0, a1), \
        tuple(jnp.asarray(x) for x in (ox, oy, ow, oh, draw, a0, a1)) \
        + (win,)


@pytest.mark.parametrize("b,m,p", [(1, 22, 4), (7, 22, 5), (16, 40, 8),
                                   (11, 3, 1)])
@pytest.mark.parametrize("moment_frac", [None, 0.5])
def test_cell_rasterize_kernel_matches_ref(b, m, p, moment_frac):
    """Pallas kernel path (padded to tiles) == pure-jnp reference path,
    including n_moment < P (the stacked student+teacher layout
    observe_all_cells uses, where only leading channels feed geometry)."""
    n_moment = None if moment_frac is None else max(1, int(p * moment_frac))
    _, args = _rasterize_inputs(b, m, p, seed=b * 100 + m)
    ref = cell_rasterize(*args, use_kernel=False, n_moment=n_moment)
    ker = cell_rasterize(*args, use_kernel=True, n_moment=n_moment)
    for name, r, k in zip(("cnt", "area", "wcx", "wcy", "wc2", "ext"),
                          ref, ker):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
    if n_moment is not None and n_moment < p:
        # gating matters: full-moment geometry must differ somewhere
        full = cell_rasterize(*args, use_kernel=False)
        assert any(not np.allclose(np.asarray(a), np.asarray(c))
                   for a, c in zip(ref[2:], full[2:]))


def test_cell_rasterize_ref_matches_gt_boxes():
    """The reference visibility/clipping rule reproduces data/render
    .gt_boxes counts and normalized areas for an always-detect teacher."""
    from repro.core.grid import DEFAULT_GRID
    from repro.data.render import gt_boxes

    (ox, oy, ow, oh, _, _, _), _ = _rasterize_inputs(3, 22, 1, seed=5)
    # always detect any visible object: draw = -1 < clip(...) >= 0 needs
    # apparent > a0, so use a0 = -1 (every visible box passes the ramp)
    draw = np.full((3, 1, 22), -1.0, np.float32)
    a0 = np.array([-1.0], np.float32)
    a1 = np.array([-0.5], np.float32)
    win = jnp.asarray(window_arrays(DEFAULT_GRID))
    cnt, area, _, _, _, _ = cell_rasterize(
        *(jnp.asarray(x) for x in (ox, oy, ow, oh, draw, a0, a1)), win)
    cnt, area = np.asarray(cnt), np.asarray(area)
    zooms = (1.0, 2.0, 3.0)
    for b in range(3):
        snap = {"pos": np.stack([ox[b], oy[b]], -1),
                "size": np.stack([ow[b], oh[b]], -1),
                "kind": np.zeros(22, int), "oid": np.arange(22), "t": 0}
        for cell in (0, 7, 12, 24):
            for zi, z in enumerate(zooms):
                gt = gt_boxes(snap, DEFAULT_GRID, cell, z)
                c = cell * len(zooms) + zi
                assert cnt[b, 0, c] == len(gt["boxes"]), (b, cell, zi)
                np.testing.assert_allclose(
                    area[b, 0, c],
                    float((gt["boxes"][:, 2] * gt["boxes"][:, 3]).sum()),
                    atol=1e-4)


# ---------------------------------------------------------------------------
# crop patchify (fused rasterize -> ViT patch-embed, detector fast path)
# ---------------------------------------------------------------------------

def _patchify_inputs(f, m, k, d, seed=0, *, shared=False, with_noise=True):
    """Random scene boxes + per-camera window subsets + patch-embed
    params, shaped like the detector provider's fast path."""
    from repro.core.grid import DEFAULT_GRID
    from repro.models.layers import conv_init

    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.uniform([0, 0], [150, 75], (f, m, 2)), jnp.float32)
    size = jnp.asarray(rng.uniform(1.5, 9.0, (f, m, 2)), jnp.float32)
    size = size.at[:, -2:].set(0.0)                 # disabled slots
    kind = jnp.asarray(rng.integers(0, 2, m), jnp.int32)
    oid = jnp.asarray(rng.integers(0, 4000, (f, m)), jnp.int32)
    wins_all = jnp.asarray(window_arrays(DEFAULT_GRID))
    if shared:
        wins = wins_all[: k]
    else:
        widx = np.stack([rng.choice(wins_all.shape[0], k, replace=False)
                         for _ in range(f)])
        wins = wins_all[jnp.asarray(widx)]
    pe = conv_init(jax.random.fold_in(KEY, seed), 16, 16, 3, d)
    noise = (0.05 * jax.random.normal(jax.random.fold_in(KEY, seed + 1),
                                      (f, 64, 64, 3))
             if with_noise else None)
    return pos, size, kind, oid, wins, pe, noise


@pytest.mark.parametrize("f,m,k,d,shared",
                         [(1, 6, 3, 8, False), (3, 22, 5, 24, False),
                          (2, 22, 4, 16, True)])
def test_crop_patchify_kernel_matches_ref(f, m, k, d, shared):
    """Pallas kernel (rasterize fused into the patch contraction, pixels
    never materialized) == render_fleet_crops + conv patchify reference,
    within fp32 tolerance — per-camera and fleet-shared window sets."""
    pos, size, kind, oid, wins, pe, noise = _patchify_inputs(
        f, m, k, d, seed=f * 100 + k, shared=shared)
    ref = crop_patchify(pos, size, kind, oid, wins, pe, patch=16, res=64,
                        noise=noise, use_kernel=False)
    ker = crop_patchify(pos, size, kind, oid, wins, pe, patch=16, res=64,
                        noise=noise, use_kernel=True)
    assert ref.shape == (f, k, 16, d)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_crop_patchify_ref_is_render_plus_embed():
    """The reference path IS the unfused pixel pipeline: rendering the
    same windows and running the backbone conv embed (vit.vit_embed
    layout) reproduces it bit-for-bit — the contract that makes the
    fast path's exhaustive mode decision-identical to the pre-shortlist
    detector provider."""
    from repro.scene_jax.render import render_fleet_crops

    pos, size, kind, oid, wins, pe, noise = _patchify_inputs(
        2, 10, 4, 12, seed=7)
    got = crop_patchify(pos, size, kind, oid, wins, pe, patch=16, res=64,
                        noise=noise, use_kernel=False)
    from repro.models.layers import conv2d

    crops = render_fleet_crops(pos, size, kind, oid, wins, res=64,
                               noise=noise)
    want = conv2d(pe, crops.reshape(8, 64, 64, 3), stride=16,
                  padding="VALID").reshape(2, 4, 16, 12)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 16, 64), (2, 100, 256), (7, 33),
                                   (1, 1, 8), (512, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],)) + 1.0
    out = rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# frame delta
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw", [(128, 128), (224, 224), (64, 256)])
def test_frame_delta_matches_ref(hw):
    H, W = hw
    cur = jax.random.uniform(KEY, (H, W, 3))
    prev = jnp.clip(cur.at[: H // 2, : W // 2].add(0.3), 0, 1)
    dq, ch, byt = frame_delta(cur, prev, tile_h=16, tile_w=128)
    ph, pw = (-H) % 16, (-W) % 128
    curp = jnp.pad(cur, ((0, ph), (0, pw), (0, 0)))
    prevp = jnp.pad(prev, ((0, ph), (0, pw), (0, 0)))
    dq_r, ch_r = frame_delta_ref(curp, prevp, tile_h=16, tile_w=128)
    np.testing.assert_array_equal(np.asarray(dq), np.asarray(dq_r[:H, :W]))
    np.testing.assert_array_equal(np.asarray(ch), np.asarray(ch_r))
    assert int(byt) > 0


def test_frame_delta_reconstruction():
    cur = jax.random.uniform(KEY, (64, 128, 3))
    prev = jnp.clip(cur + 0.2, 0, 1)       # every tile changes
    dq, ch, _ = frame_delta(cur, prev, tile_h=16, tile_w=128)
    rec = apply_delta(prev, dq)
    assert float(jnp.max(jnp.abs(rec - cur))) < 1.0 / 127 + 1e-3


def test_frame_delta_identical_frames_send_nothing():
    cur = jax.random.uniform(KEY, (64, 128, 3))
    dq, ch, byt = frame_delta(cur, cur)
    assert int(ch.sum()) == 0
    assert not bool(jnp.any(dq != 0))
