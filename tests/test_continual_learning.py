"""Continual distillation integration: the fine-tune loop must actually
teach the detector heads while leaving the backbone frozen."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import continual
from repro.core.distill import rank_agreement, spearman, teacher_labels
from repro.models import detector as det

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("madeye-approx")
    params = det.detector_init(KEY, cfg)
    # teacher targets: one box per image at a grid of centers
    B = 8
    rng = np.random.default_rng(0)
    images = rng.normal(0.5, 0.2, (B, cfg.img_res, cfg.img_res, 3)) \
        .astype(np.float32)
    t_boxes = [np.array([[0.3 + 0.05 * i % 0.4, 0.4, 0.2, 0.3]])
               for i in range(B)]
    t_classes = [np.array([i % 2]) for i in range(B)]
    targets = teacher_labels(t_boxes, t_classes, cfg.max_boxes)
    return cfg, params, jnp.asarray(images), targets


def test_finetune_reduces_loss(setup):
    cfg, params, images, targets = setup
    opt = continual.init_finetune(params)
    boxes = jnp.asarray(targets.boxes)
    classes = jnp.asarray(targets.classes)
    valid = jnp.asarray(targets.valid)
    losses = []
    for _ in range(12):
        params, opt, loss = continual.finetune_step(
            params, opt, cfg, images, boxes, classes, valid, lr=3e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_finetune_keeps_backbone_frozen(setup):
    cfg, params, images, targets = setup
    opt = continual.init_finetune(params)
    before = jax.tree.map(lambda x: x.copy(), params["backbone"])
    params2, _, _ = continual.finetune_step(
        params, opt, cfg, images, jnp.asarray(targets.boxes),
        jnp.asarray(targets.classes), jnp.asarray(targets.valid))
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(params2["backbone"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_teacher_labels_static_shapes():
    t = teacher_labels([np.zeros((50, 4))], [np.zeros(50, int)], max_boxes=8)
    assert t.boxes.shape == (1, 8, 4)
    assert t.valid.all()
    t2 = teacher_labels([np.zeros((0, 4))], [np.zeros(0, int)], max_boxes=8)
    assert not t2.valid.any()


def test_rank_metrics():
    assert rank_agreement(np.array([0.9, 0.1]), np.array([0.8, 0.2])) == 1.0
    assert rank_agreement(np.array([0.1, 0.9]), np.array([0.8, 0.2])) == 0.0
    assert spearman(np.array([3.0, 2.0, 1.0]),
                    np.array([30.0, 20.0, 10.0])) == pytest.approx(1.0)
    assert spearman(np.array([1.0, 2.0, 3.0]),
                    np.array([30.0, 20.0, 10.0])) == pytest.approx(-1.0)
