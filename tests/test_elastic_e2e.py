"""Elastic re-sharding end to end: checkpoint on one mesh, resume on a
smaller one (the node-failure path a 1000-node job actually takes)."""
import os
import subprocess
import sys
import textwrap


def test_checkpoint_resumes_on_smaller_mesh(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        from repro.train.elastic import reshard, shrink_mesh, rebalance_batch

        d = r"{tmp_path}/ckpt"
        # train on a (4,) data mesh
        mesh4 = jax.make_mesh((4,), ("data",))
        params = {{"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}}
        sh4 = {{"w": NamedSharding(mesh4, P("data", None))}}
        params = reshard(params, sh4)
        ckpt.save(d, 10, params)

        # two "nodes" die -> resume on a (2,) mesh
        mesh2 = shrink_mesh(mesh4, "data", 2)
        assert mesh2.devices.size == 2
        restored, manifest = ckpt.restore(d, 10, params)
        sh2 = {{"w": NamedSharding(mesh2, P("data", None))}}
        resharded = reshard(restored, sh2)
        np.testing.assert_array_equal(
            np.asarray(resharded["w"]),
            np.arange(32, dtype=np.float32).reshape(8, 4))
        # per-replica batch is preserved when DP width shrinks
        assert rebalance_batch(256, old_dp=4, new_dp=2) == 128
        # and training can continue: a step on the new mesh
        def step(p, x):
            return {{"w": p["w"] - 0.1 * (p["w"] @ x)}}
        x = jnp.eye(4)
        out = jax.jit(step)(resharded, x)
        assert out["w"].shape == (8, 4)
        print("ELASTIC_OK")
    """)
    pypath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": pypath},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
