"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs (full configs run only via dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.train import trainer

KEY = jax.random.PRNGKey(0)


def _smoke_shape(cfg):
    if cfg.family == "lm":
        return ShapeSpec("smoke", "train", seq_len=16, global_batch=2)
    return ShapeSpec("smoke", "train", img_res=cfg.img_res, global_batch=2)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_train_step(arch):
    cfg = get_smoke_config(arch)
    shape = _smoke_shape(cfg)
    ts = trainer.make_train_step(cfg, lr=1e-3)
    params = ts.init_params(KEY)
    opt = ts.init_opt(params)

    # synthetic batch from the same specs the dry-run lowers
    specs = trainer.batch_specs(cfg, shape)
    batch = {}
    for name, sds in specs.items():
        k = jax.random.fold_in(KEY, abs(hash(name)) % 999)
        if sds.dtype == jnp.int32:
            hi = getattr(cfg, "vocab", getattr(cfg, "n_classes", 2))
            batch[name] = jax.random.randint(k, sds.shape, 0, hi)
        elif sds.dtype == jnp.bool_:
            batch[name] = jnp.ones(sds.shape, bool)
        else:
            batch[name] = jax.random.normal(k, sds.shape, sds.dtype) * 0.1

    params2, opt2, metrics = jax.jit(ts.step)(params, opt, batch, KEY)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert float(metrics["grad_norm"]) > 0
    # params actually moved (note: bf16 dtype.kind is 'V', so compare all
    # floating leaves via issubdtype)
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
        if jnp.issubdtype(a.dtype, jnp.floating))
    assert moved, f"{arch}: optimizer step did not update params"


@pytest.mark.parametrize("arch", ["stablelm-3b", "stablelm-12b"])
def test_dense_lm_decode_matches_forward(arch):
    """Prefill+decode must agree with the full forward (exactness of the
    KV-cache serving path)."""
    from repro.models import kvcache as kvc
    from repro.models.transformer import lm_forward, lm_init

    cfg = get_smoke_config(arch)
    params = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)

    full = lm_forward(params, cfg, toks)
    logits_p, cache = kvc.gqa_prefill(params, cfg, toks[:, :8], max_seq=16)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full[:, :8], np.float32),
                               atol=2e-2)
    for i in range(8, 12):
        logits_d, cache = kvc.gqa_decode_step(
            params, cfg, toks[:, i: i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, i], np.float32), atol=2e-2,
            err_msg=f"{arch} decode step {i} diverged from forward")


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "kimi-k2-1t-a32b"])
def test_moe_lm_decode_runs(arch):
    from repro.models import kvcache as kvc
    from repro.models.moe_lm import moe_lm_init

    cfg = get_smoke_config(arch)
    params = moe_lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    if cfg.mla:
        logits, cache = kvc.mla_prefill(params, cfg, toks, max_seq=16)
        logits, cache = kvc.mla_decode_step(params, cfg, toks[:, :1], cache)
    else:
        logits, cache = kvc.moe_gqa_prefill(params, cfg, toks, max_seq=16)
        logits, cache = kvc.moe_gqa_decode_step(params, cfg, toks[:, :1],
                                                cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache.length) == 9


def test_mla_cache_is_compressed():
    """MLA's whole point: cache bytes/token ~ (lora + rope), far below
    GQA's 2 * Hkv * Dh."""
    from repro.models import kvcache as kvc
    cfg = get_smoke_config("deepseek-v3-671b")
    mla = kvc.init_mla_cache(cfg, 1, 8)
    mla_bytes = (mla.kv_latent.size + mla.k_rope.size) * 2
    gqa_equiv = 2 * cfg.n_layers * 8 * cfg.n_heads * cfg.resolved_head_dim * 2
    assert mla_bytes < gqa_equiv / 2


def test_detector_smoke():
    from repro.configs import get_smoke_config as gsc
    from repro.models import detector as det
    cfg = gsc("madeye-approx")
    params = det.detector_init(KEY, cfg)
    img = jax.random.normal(KEY, (2, cfg.img_res, cfg.img_res, 3))
    d = det.detector_forward(params, cfg, img)
    assert d.boxes.shape == (2, cfg.max_boxes, 4)
    assert d.scores.shape == (2, cfg.max_boxes)
    assert not bool(jnp.isnan(d.boxes).any())
    assert bool(jnp.all((d.scores >= 0) & (d.scores <= 1)))


def test_diffusion_samplers_run():
    from repro.models import dit as dit_mod
    from repro.models import diffusion as diff
    cfg = get_smoke_config("dit-l2")
    params = dit_mod.dit_init(KEY, cfg)
    out = diff.dit_sample(params, cfg, KEY, batch=1, n_steps=2)
    assert out.shape[-1] == cfg.latent_channels
    assert not bool(jnp.isnan(out).any())

    from repro.models import mmdit as mm
    cfg2 = get_smoke_config("flux-dev")
    params2 = mm.mmdit_init(KEY, cfg2)
    out2 = diff.rf_sample(params2, cfg2, KEY, batch=1, n_steps=2)
    assert not bool(jnp.isnan(out2).any())


def test_vision_features_shape():
    from repro.models import vit as vit_mod
    cfg = get_smoke_config("vit-s16")
    params = vit_mod.vit_init(KEY, cfg)
    img = jax.random.normal(KEY, (2, cfg.img_res, cfg.img_res, 3))
    f = vit_mod.vit_features(params, cfg, img)
    g = cfg.img_res // cfg.patch
    assert f.shape == (2, g, g, cfg.d_model)
