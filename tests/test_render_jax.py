"""In-step approximation model: renderer parity + detector-provider
determinism + compile-once inference.

Three contracts pin the camera-side distillation loop (paper §3.4):

  * the jnp rasterizer (scene_jax.render) is pixel-identical to the host
    renderer `data/render.render_image` at noise=0 — same visibility
    rule, pixel rounding, painter order, and oid shading — so the
    detector scores the same images in-scan that the host pipeline and
    the distillation trainer render;
  * `DetectorProvider` decisions derive only from per-camera keys
    (fold_in(camera_key, frame), the SceneProvider discipline), so a
    camera's episode is bit-identical regardless of fleet size;
  * the hoisted `detector_counts_and_areas` jit treats the score
    threshold as a traced scalar — sweeping thresholds (or calling under
    vmap) never recompiles.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core.tradeoff import BudgetConfig
from repro.data.render import render_image
from repro.fleet import (
    fleet_config,
    fleet_statics,
    make_detector_provider,
    run_fleet_episode,
    workload_spec,
)
from repro.kernels.cell_rasterize.ops import window_arrays
from repro.scene_jax import (
    SceneSpec,
    advance_scene,
    init_scene,
    render_crop,
    render_fleet_crops,
    render_noise,
    scene_fleet_params,
)
from repro.scene_jax.scene import kind_mask

GRID = DEFAULT_GRID
WORKLOAD = Workload((
    Query("yolov4", "person", "count"),
    Query("ssd", "car", "detect"),
    Query("frcnn", "person", "binary"),
    Query("tiny-yolov4", "person", "agg_count"),
))
BUDGET = BudgetConfig(fps=2.0)
ZOOMS = (1.0, 2.0, 3.0)


def _scene_and_snapshot(seed: int, frames: int = 7):
    """One camera's SceneState plus the numpy-renderer view of it."""
    spec = SceneSpec()
    params, rng = scene_fleet_params(spec, 1, scene_seeds=[seed])
    st = init_scene(spec, params, rng)
    st = advance_scene(spec, params, rng, st, jnp.zeros(1, jnp.int32),
                       frames)
    kinds = np.asarray(kind_mask(spec))
    snap = {"pos": np.asarray(st.pos[0], np.float64),
            "size": np.asarray(st.size[0], np.float64),
            "kind": kinds,
            "oid": np.asarray(st.oid[0], np.int64),
            "t": 0}
    return spec, st, kinds, snap


# ---------------------------------------------------------------------------
# jnp renderer vs data/render.render_image
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [5, 11])
def test_render_crop_pixel_parity(seed):
    """Every (cell, zoom) crop matches the host renderer to float32
    rounding on a live scene — geometry, visibility cut, paint order,
    class colors and oid shades all agree."""
    _, st, kinds, snap = _scene_and_snapshot(seed)
    wins = window_arrays(GRID, ZOOMS)
    for cell in (0, 3, 7, 12, 18, 24):
        for zi, z in enumerate(ZOOMS):
            ref = render_image(snap, GRID, cell, z, res=64, noise=0.0)
            got = np.asarray(render_crop(
                st.pos[0], st.size[0], jnp.asarray(kinds), st.oid[0],
                jnp.asarray(wins[cell * len(ZOOMS) + zi])))
            np.testing.assert_allclose(got, ref, atol=1e-6,
                                       err_msg=f"cell {cell} zoom {z}")


def test_render_parity_scene_has_objects():
    """The parity scenes actually paint boxes (a blank-background match
    would be vacuous) and crops land in [0, 1]."""
    _, st, kinds, snap = _scene_and_snapshot(5)
    wins = window_arrays(GRID, ZOOMS)
    crops = np.asarray(render_fleet_crops(
        st.pos, st.size, jnp.asarray(kinds), st.oid, jnp.asarray(wins),
        res=64))
    assert crops.shape == (1, wins.shape[0], 64, 64, 3)
    assert crops.min() >= 0.0 and crops.max() <= 1.0
    bg = np.asarray(render_fleet_crops(
        st.pos + 1e6, st.size, jnp.asarray(kinds), st.oid,
        jnp.asarray(wins), res=64))
    painted = np.abs(crops - bg) > 1e-6
    assert painted.any(), "no object pixels rendered anywhere"


def test_render_noise_is_per_camera_and_salted():
    """Noise folds from the camera key: same key -> same image, distinct
    cameras/frames -> distinct images; stream is fleet-size independent."""
    spec = SceneSpec()
    _, rng3 = scene_fleet_params(spec, 3, scene_seeds=[5, 9, 5])
    _, rng1 = scene_fleet_params(spec, 1, scene_seeds=[5])
    n3 = np.asarray(render_noise(rng3, jnp.full(3, 4, jnp.int32), 16))
    n1 = np.asarray(render_noise(rng1, jnp.full(1, 4, jnp.int32), 16))
    np.testing.assert_array_equal(n3[0], n3[2])      # same camera seed
    np.testing.assert_array_equal(n3[0], n1[0])      # fleet-size invariant
    assert not np.array_equal(n3[0], n3[1])          # cameras decorrelated
    n3b = np.asarray(render_noise(rng3, jnp.full(3, 5, jnp.int32), 16))
    assert not np.array_equal(n3[0], n3b[0])         # frames decorrelated


# ---------------------------------------------------------------------------
# DetectorProvider: fleet-scan determinism across fleet sizes
# ---------------------------------------------------------------------------

DECISION_FIELDS = ("explored", "order", "n_explored", "zooms", "sent",
                   "k_send")


def test_detector_provider_deterministic_across_fleet_sizes():
    """Camera decisions under the in-scan render+infer provider depend
    only on (seed, scene_seed) — the same camera embedded in a 1-fleet
    and a 3-fleet produces the identical episode, identically-seeded
    cameras stay in lockstep, and differently-seeded cameras diverge."""
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)

    p3, st3 = make_detector_provider(GRID, WORKLOAD, cfg, n_cameras=3,
                                     n_steps=6, scene_seeds=[5, 9, 5])
    _, out3 = run_fleet_episode(cfg, spec, statics, st3, p3)
    p1, st1 = make_detector_provider(GRID, WORKLOAD, cfg, n_cameras=1,
                                     n_steps=6, scene_seeds=[5])
    _, out1 = run_fleet_episode(cfg, spec, statics, st1, p1)

    for name in DECISION_FIELDS:
        a3 = np.asarray(getattr(out3, name))
        a1 = np.asarray(getattr(out1, name))
        np.testing.assert_array_equal(a3[:, 0], a3[:, 2],
                                      err_msg=f"{name}: lockstep")
        np.testing.assert_array_equal(a3[:, 0], a1[:, 0],
                                      err_msg=f"{name}: fleet size")
    np.testing.assert_allclose(np.asarray(out3.pred_acc)[:, 0],
                               np.asarray(out1.pred_acc)[:, 0], atol=1e-6)
    assert not np.array_equal(np.asarray(out3.explored)[:, 0],
                              np.asarray(out3.explored)[:, 1])
    # the detector actually fired: predictions are not uniformly zero
    assert float(np.asarray(out3.pred_acc).max()) > 0.0


def test_detector_shortlist_deterministic_across_fleet_sizes():
    """The candidate shortlist is a pure per-camera function of
    controller state, so the sparse fast path keeps the provider's
    determinism discipline: the same camera embedded in a 1-fleet and a
    3-fleet runs the identical episode, and the shortlist genuinely
    bites (decisions differ from a camera watching another world)."""
    cfg = fleet_config(GRID, BUDGET)
    spec = workload_spec(WORKLOAD)
    statics = fleet_statics(GRID)

    kw = dict(n_steps=4, shortlist_k=18)
    p3, st3 = make_detector_provider(GRID, WORKLOAD, cfg, n_cameras=3,
                                     scene_seeds=[5, 9, 5], **kw)
    _, out3 = run_fleet_episode(cfg, spec, statics, st3, p3)
    p1, st1 = make_detector_provider(GRID, WORKLOAD, cfg, n_cameras=1,
                                     scene_seeds=[5], **kw)
    _, out1 = run_fleet_episode(cfg, spec, statics, st1, p1)
    for name in DECISION_FIELDS:
        a3 = np.asarray(getattr(out3, name))
        a1 = np.asarray(getattr(out1, name))
        np.testing.assert_array_equal(a3[:, 0], a3[:, 2],
                                      err_msg=f"{name}: lockstep")
        np.testing.assert_array_equal(a3[:, 0], a1[:, 0],
                                      err_msg=f"{name}: fleet size")
    assert not np.array_equal(np.asarray(out3.explored)[:, 0],
                              np.asarray(out3.explored)[:, 1])
    assert float(np.asarray(out3.pred_acc).max()) > 0.0


# ---------------------------------------------------------------------------
# hoisted engine jit: threshold sweeps never recompile
# ---------------------------------------------------------------------------

def test_counts_and_areas_compiles_once_across_thresholds():
    from repro.configs import get_smoke_config
    from repro.models.detector import detector_init
    from repro.serving import engine

    cfg = get_smoke_config("madeye-approx")
    params = detector_init(jax.random.PRNGKey(0), cfg)
    eng = engine.InferenceEngine(cfg, params)
    imgs = jax.random.uniform(jax.random.PRNGKey(1),
                              (4, cfg.img_res, cfg.img_res, 3))
    c_all, _ = eng.counts_and_areas(imgs, score_thresh=0.0)
    assert int(jnp.sum(c_all)) == 4 * cfg.max_boxes
    size = engine.detector_counts_and_areas._cache_size()
    c_hi, a_hi = eng.counts_and_areas(imgs, score_thresh=0.99)
    assert engine.detector_counts_and_areas._cache_size() == size, \
        "score_thresh must be traced, not a retrace key"
    assert int(jnp.sum(c_hi)) <= int(jnp.sum(c_all))

    # the in-step path: vmapped over a fleet axis, thresholds still free
    fleet = jax.random.uniform(jax.random.PRNGKey(2),
                               (3, 4, cfg.img_res, cfg.img_res, 3))
    vm = jax.vmap(
        lambda im, t: engine.detector_counts_and_areas(params, cfg, im, t),
        in_axes=(0, None))
    vm(fleet, jnp.float32(0.2))
    size = engine.detector_counts_and_areas._cache_size()
    counts, _ = vm(fleet, jnp.float32(0.7))
    assert engine.detector_counts_and_areas._cache_size() == size
    assert counts.shape == (3, 4)
