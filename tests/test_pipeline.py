"""Pipeline parallelism: exactness vs sequential execution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import (
    make_pipelined_forward,
    split_stages,
)
from repro.launch.mesh import make_debug_mesh

KEY = jax.random.PRNGKey(0)


def _body(lp, x, extra):
    return jnp.tanh(x @ lp["w"] + lp["b"])


def _stack(n_layers, d):
    keys = jax.random.split(KEY, n_layers)
    return {
        "w": jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in keys]),
        "b": jnp.stack([jnp.zeros(d) for _ in keys]),
    }


def _sequential(params, x, extra=None):
    def step(c, lp):
        return _body(lp, c, extra), None
    y, _ = jax.lax.scan(step, x, params)
    return y


def test_split_stages_shapes():
    p = _stack(8, 4)
    staged = split_stages(p, 4)
    assert staged["w"].shape == (4, 2, 4, 4)


def test_pipeline_matches_sequential_single_stage():
    """S=1 degenerate pipeline == plain scan (runs on the 1-CPU mesh)."""
    d, L, M, mb = 4, 6, 3, 2
    params = _stack(L, d)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, mb, d))

    mesh = make_debug_mesh(1, 1)
    staged = split_stages(params, 1)
    fn = make_pipelined_forward(_body, mesh, 1)
    out = fn(staged, x, None)

    ref = jnp.stack([_sequential(params, x[i]) for i in range(M)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_pipeline_matches_sequential_multi_stage():
    """S=4 stages on 4 forced host devices."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import make_pipelined_forward, \\
            split_stages

        KEY = jax.random.PRNGKey(0)
        d, L, M, mb, S = 4, 8, 5, 2, 4
        keys = jax.random.split(KEY, L)
        params = {
            "w": jnp.stack([jax.random.normal(k, (d, d)) * 0.3
                            for k in keys]),
            "b": jnp.stack([jnp.zeros(d) for _ in keys]),
        }
        def body(lp, x, extra):
            return jnp.tanh(x @ lp["w"] + lp["b"])

        x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, mb, d))
        mesh = jax.make_mesh((1, S), ("data", "model"))
        fn = make_pipelined_forward(body, mesh, S)
        out = fn(split_stages(params, S), x, None)

        def seq(x1):
            def step(c, lp):
                return body(lp, c, None), None
            y, _ = jax.lax.scan(step, x1, params)
            return y
        ref = jnp.stack([seq(x[i]) for i in range(M)])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        print("PIPELINE_OK")
    """)
    pypath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": pypath},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
