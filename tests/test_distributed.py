"""Sharding-rule and collective-parser tests (single-device safe)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.distributed.sharding import collective_bytes
from repro.launch.mesh import make_debug_mesh


def test_collective_bytes_parser():
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(f32[1,1024] %x), dims={0}
  %ar = bf16[4096]{0} all-reduce(bf16[4096] %y), to_apply=%add
  %rs = f32[256,8]{1,0} reduce-scatter(f32[2048,8] %z), dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(f32[32,32] %w), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8] %v), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 4
    assert out["all-reduce"] == 4096 * 2
    assert out["reduce-scatter"] == 256 * 8 * 4
    assert out["all-to-all"] == 32 * 32 * 4
    assert out["collective-permute"] == 8 * 4


def test_param_rules_respect_divisibility():
    """A dim that doesn't divide the mesh axis must not be sharded."""
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    tree = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((7, 13), jnp.float32)}}}
    sh = shd.param_shardings(tree, mesh)
    spec = sh["attn"]["wq"]["w"].spec
    assert all(s is None for s in spec)
    # and a divisible one IS sharded (FSDP on d_in, TP on d_out)
    tree2 = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((4096, 4096),
                                                       jnp.float32)}}}
    spec2 = shd.param_shardings(tree2, mesh)["attn"]["wq"]["w"].spec
    assert spec2[0] is not None and spec2[1] == "model"


def test_param_rules_smoke_config_tree():
    """Every leaf of a real model gets a valid sharding on a 1x1 mesh."""
    from repro.models.moe_lm import moe_lm_init
    mesh = make_debug_mesh(1, 1)
    cfg = get_smoke_config("deepseek-v3-671b")
    p_shape = jax.eval_shape(
        lambda k: moe_lm_init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    sh = shd.param_shardings(p_shape, mesh)
    n = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n == len(jax.tree.leaves(
        p_shape, is_leaf=lambda x: hasattr(x, "shape")))


def test_batch_sharding_leading_dim():
    mesh = make_debug_mesh(1, 1)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
    sh = shd.batch_shardings(batch, mesh)
    # on a 1-wide mesh everything divides; spec[0] is the dp axis tuple
    assert sh["tokens"].spec[0] is not None


def test_cell_builder_constructs_all_assigned():
    """build_cell must produce a coherent CellSpec for every (arch, shape)
    on the debug mesh (structure only — full lowering runs in dryrun)."""
    from repro.configs import ASSIGNED_ARCHS, get_config, shapes_for
    from repro.launch.steps import build_cell
    mesh = make_debug_mesh(1, 1)
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            cell = build_cell(arch, shape.name, mesh)
            n_args = len(jax.tree.leaves(cell.args))
            n_sh = len(jax.tree.leaves(
                cell.in_shardings, is_leaf=lambda x: hasattr(x, "spec")))
            assert n_args == n_sh, f"{arch}/{shape.name}: args vs shardings"


def test_ring_reduce_attend_matches_full_attention():
    """Flash-decode combine (single shard == exact attention)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed.collectives import ring_reduce_attend
    import math

    mesh = make_debug_mesh(1, 1)
    B, S, H, D = 2, 32, 4, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    scale = 1.0 / math.sqrt(D)

    fn = shard_map(
        lambda q, k, v: ring_reduce_attend(q, k, v, "model", scale=scale),
        mesh=mesh, in_specs=(P(), P(None, "model"), P(None, "model")),
        out_specs=P())
    out = fn(q, k, v)

    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    w = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
