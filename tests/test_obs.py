"""Fleet telemetry layer (repro.obs).

Pins the three observability channels and their contracts:

  * in-scan FleetMetrics are decision-invisible — metrics off/on give
    bit-identical chosen orientations and pred_acc across all three
    providers, and the steady-state overhead with them on stays < 15%;
  * the in-scan `chosen_rank`/`shortlist_hit` outputs match their host
    replay definitions (bench_rank_quality._chosen_rank; exhaustive
    shortlists always hit);
  * span traces export well-formed Chrome trace JSON and cost nothing
    when inactive;
  * the JSONL telemetry event schema round-trips and validates, both
    via the API and through `serve --fleet --telemetry -`.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.fleet import (
    FleetResult,
    FleetRunSpec,
    fleet_config,
    fleet_statics,
    make_detector_provider,
    materialize_scene_tables,
    prepare_fleet_run,
    run_fleet,
    run_fleet_episode,
    workload_spec,
)
from repro.obs import (
    METRIC_KEYS,
    MetricsSpec,
    Tracer,
    active_tracer,
    episode_events,
    median_valid_rank,
    read_events,
    span,
    summarize_metrics,
    tracing,
    validate_event,
    write_events,
)


def _run(provider, metrics=None, **kw):
    spec = FleetRunSpec(provider=provider, n_cameras=2, n_steps=5,
                        budget={"fps": 2.0}, metrics=metrics, **kw)
    return run_fleet(spec)


# ---------------------------------------------------------------------------
# MetricsSpec + decision parity
# ---------------------------------------------------------------------------

def test_metrics_spec_keys_and_normalization():
    assert MetricsSpec().keys() == tuple(
        k for ks in METRIC_KEYS.values() for k in ks)
    assert MetricsSpec(enabled=False).keys() == ()
    assert MetricsSpec(rank=False).keys() == (
        "ewma_label_mean", "frames_sent", "k_send", "n_explored",
        "cells_visited", "shortlist_hit")
    # the spec field normalizes bools/dicts and round-trips JSON
    assert FleetRunSpec(metrics=True).metrics == MetricsSpec()
    assert FleetRunSpec(metrics=False).metrics is None
    assert FleetRunSpec(metrics={"enabled": False}).metrics is None
    s = FleetRunSpec(metrics={"budget": False})
    s2 = FleetRunSpec.from_json(s.to_json())
    assert s2.metrics == MetricsSpec(budget=False)


@pytest.mark.parametrize("provider,kw", [
    ("tables", {}),
    ("scene", {}),
    ("detector", {"shortlist_k": 18}),
])
def test_metrics_off_on_decision_parity(provider, kw):
    """The acceptance gate: metrics=off compiles the exact prior scan,
    metrics=on must not perturb a single decision."""
    off = _run(provider, metrics=None, **kw)
    on = _run(provider, metrics=True, **kw)
    assert np.array_equal(np.asarray(off.out.chosen),
                          np.asarray(on.out.chosen))
    assert np.array_equal(np.asarray(off.out.pred_acc),
                          np.asarray(on.out.pred_acc))
    assert off.metrics is None
    assert sorted(on.metrics) == sorted(MetricsSpec().keys())
    e, f = on.n_steps, on.n_cameras
    assert all(np.asarray(v).shape[:2] == (e, f)
               for v in on.metrics.values())


def test_metric_group_gating_shrinks_pytree():
    r = _run("scene", metrics={"ewma": False, "rank": False})
    assert sorted(r.metrics) == sorted(
        MetricsSpec(ewma=False, rank=False).keys())


def test_budget_metrics_match_step_outputs():
    r = _run("scene", metrics=True)
    out_sent = np.asarray(r.out.sent).sum(-1)
    assert np.array_equal(np.asarray(r.metrics["frames_sent"]), out_sent)
    assert np.array_equal(np.asarray(r.metrics["k_send"]),
                          np.asarray(r.out.k_send))
    visited = np.asarray(r.metrics["cells_visited"])
    assert np.all(np.diff(visited, axis=0) >= 0)          # monotone
    assert np.all(visited >= 1)


# ---------------------------------------------------------------------------
# shortlist hit-rate + chosen rank semantics
# ---------------------------------------------------------------------------

def _detector_run(shortlist_k, n_steps=6):
    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig

    wl = FleetRunSpec(budget={"fps": 2.0}).workload_obj()
    cfg = fleet_config(DEFAULT_GRID, BudgetConfig(fps=2.0))
    spec = workload_spec(wl)
    statics = fleet_statics(DEFAULT_GRID)
    provider, st0 = make_detector_provider(
        DEFAULT_GRID, wl, cfg, n_cameras=1, n_steps=n_steps,
        scene_seeds=[3], shortlist_k=shortlist_k)
    return cfg, spec, statics, st0, provider


def test_shortlist_hit_rate_one_when_exhaustive():
    """shortlist_k = N*Z keeps every window, so the oracle-best cell is
    in the candidate set at every step by construction."""
    cfg, spec, statics, st0, provider = _detector_run(None)
    c = provider.scene.windows.shape[0]   # already all N*Z windows
    assert provider.shortlist_k == c
    _, _, m = run_fleet_episode(cfg, spec, statics, st0, provider,
                                metrics=MetricsSpec())
    assert np.all(np.asarray(m["shortlist_hit"]) == 1.0)


def test_shortlist_hit_rate_bounded_when_sparse():
    cfg, spec, statics, st0, provider = _detector_run(18)
    _, _, m = run_fleet_episode(cfg, spec, statics, st0, provider,
                                metrics=MetricsSpec())
    hit = np.asarray(m["shortlist_hit"])
    assert hit.shape == (6, 1)
    assert np.all((hit == 0.0) | (hit == 1.0))


def test_chosen_rank_matches_host_replay():
    """The in-scan chosen_rank IS bench_rank_quality's replay metric:
    grade the same episode both ways and require equality step for
    step (None on the host side == 0 in-scan)."""
    from benchmarks.bench_rank_quality import _chosen_rank

    cfg, spec, statics, st0, provider = _detector_run(None, n_steps=8)
    scene = provider.scene
    _, out, m = run_fleet_episode(cfg, spec, statics, st0, scene,
                                  metrics=MetricsSpec())
    acc = np.asarray(materialize_scene_tables(
        cfg, spec, statics, st0, scene).acc_true)
    got = np.asarray(m["chosen_rank"])[:, 0]
    want = [_chosen_rank(acc, out, e) or 0 for e in range(8)]
    assert got.tolist() == want
    assert any(r > 0 for r in want)       # episode is actually gradable
    assert median_valid_rank(got) == float(
        np.median([r for r in want if r > 0]))


def test_median_valid_rank_degenerate():
    assert median_valid_rank(np.zeros((4, 2), np.int32)) == 0.0
    assert median_valid_rank(np.array([0, 3, 1, 0, 2])) == 2.0


# ---------------------------------------------------------------------------
# metrics overhead
# ---------------------------------------------------------------------------

def test_metrics_overhead_under_15_percent():
    """Pinned acceptance bound: the full MetricsSpec adds < 15% to the
    steady-state detector scan (quick-bench shape)."""
    spec = FleetRunSpec(provider="detector", n_cameras=8, n_steps=3,
                        seed=3, budget={"fps": 3.0},
                        provider_kwargs={"scene_seeds": list(range(8))})
    prep = prepare_fleet_run(spec)

    def steady(metrics):
        jax.block_until_ready(prep.episode(metrics=metrics))  # compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(prep.episode(metrics=metrics))
            best = min(best, time.perf_counter() - t0)
        return best

    base = steady(MetricsSpec(enabled=False))
    with_m = steady(MetricsSpec())
    assert with_m < 1.15 * base, (
        f"metrics overhead {with_m / base:.2f}x exceeds 1.15x "
        f"({base * 1e3:.1f}ms -> {with_m * 1e3:.1f}ms)")


# ---------------------------------------------------------------------------
# timings split + throughput floor
# ---------------------------------------------------------------------------

def test_run_fleet_timings_split():
    r = _run("tables")
    t = r.timings
    assert set(t) == {"build_s", "compile_s", "steady_s", "episode_s"}
    assert t["episode_s"] == t["compile_s"] + t["steady_s"]
    assert t["compile_s"] > 0 and t["steady_s"] > 0
    assert r.camera_steps_per_s == \
        r.n_cameras * r.n_steps / max(t["steady_s"], 1e-9)


def test_camera_steps_per_s_floor_and_fallback():
    base = _run("tables")
    # steady_s preferred; zero/absent timings hit the 1e-9 floor
    # instead of dividing by zero
    r = dataclasses.replace(base, timings={"steady_s": 0.0})
    assert r.camera_steps_per_s == r.n_cameras * r.n_steps / 1e-9
    r = dataclasses.replace(base, timings={})
    assert r.camera_steps_per_s == r.n_cameras * r.n_steps / 1e-9
    # legacy results (episode_s only) still report a rate
    r = dataclasses.replace(base, timings={"episode_s": 2.0})
    assert r.camera_steps_per_s == r.n_cameras * r.n_steps / 2.0
    r = dataclasses.replace(
        base, timings={"episode_s": 2.0, "steady_s": 0.5})
    assert r.camera_steps_per_s == r.n_cameras * r.n_steps / 0.5


def test_result_json_drops_metrics():
    r = _run("tables", metrics=True)
    r2 = FleetResult.from_json(r.to_json())
    assert r2.metrics is None and r2.out is None and r2.state is None
    assert r2.spec.metrics == MetricsSpec()
    assert r2.accuracy == pytest.approx(r.accuracy)


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------

def test_span_is_noop_without_tracer():
    assert active_tracer() is None
    with span("anything", x=1):
        pass                              # shared nullcontext, no error
    assert active_tracer() is None


def test_tracing_records_chrome_events(tmp_path):
    path = str(tmp_path / "trace.json")
    with tracing(path) as tr:
        with span("outer", provider="scene"):
            with span("inner"):
                pass
    assert active_tracer() is None        # restored on exit
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    names = [e["name"] for e in evs]
    assert names == ["inner", "outer"]    # completion order
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0 and "ts" in e
    outer = evs[1]
    assert outer["args"] == {"provider": "scene"}
    assert tr.to_chrome()["traceEvents"] == evs


def test_run_fleet_emits_fleet_spans(tmp_path):
    path = str(tmp_path / "trace.json")
    with tracing(path):
        _run("tables")
    names = {e["name"] for e in json.load(open(path))["traceEvents"]}
    assert {"fleet/build", "fleet/compile", "fleet/steady"} <= names


def test_tracer_non_json_args_stringified():
    tr = Tracer()
    with tr.span("s", arr=np.arange(3)):
        pass
    assert isinstance(tr.events[0]["args"]["arr"], str)


# ---------------------------------------------------------------------------
# JSONL telemetry events
# ---------------------------------------------------------------------------

def test_validate_event_rejects_malformed():
    with pytest.raises(ValueError, match="unknown event type"):
        validate_event({"event": "nope"})
    with pytest.raises(ValueError, match="missing keys"):
        validate_event({"event": "run_end", "schema": 1})
    with pytest.raises(ValueError, match="cameras.health"):
        validate_event({"event": "steps", "schema": 1, "step0": 0,
                        "step1": 4, "acc_mean": 0.5, "frames_sent": 2,
                        "cameras": {"acc_mean": [], "frames_sent": [],
                                    "n_explored_mean": []}})


def test_episode_events_schema_roundtrip(tmp_path):
    r = _run("scene", metrics=True)
    events = list(episode_events(r, chunk=2))
    assert [e["event"] for e in events] == \
        ["run_start"] + ["steps"] * 3 + ["run_end"]
    start, steps, end = events[0], events[1], events[-1]
    assert start["spec"]["provider"] == "scene"
    assert start["metrics"] is True
    assert (steps["step0"], steps["step1"]) == (0, 2)
    cams = steps["cameras"]
    assert len(cams["health"]) == r.n_cameras
    assert set(cams["health"]) <= {"ok", "idle", "lagging"}
    # metrics enrichment present when the run carried FleetMetrics
    assert len(cams["ewma_label"]) == r.n_cameras
    assert end["metrics_summary"]["shortlist_hit_rate"] == \
        [1.0] * r.n_cameras
    assert end["metrics_summary"] == summarize_metrics(r.metrics)
    assert json.dumps(events) is not None  # JSON-native end to end

    path = str(tmp_path / "tel.jsonl")
    assert write_events(iter(events), path) == len(events)
    assert read_events(path) == events
    # append mode: a second run extends the log
    write_events(iter(events), path)
    assert len(read_events(path)) == 2 * len(events)


def test_episode_events_requires_device_outputs():
    r = FleetResult.from_json(_run("tables").to_json())
    with pytest.raises(ValueError, match="stripped"):
        next(episode_events(r))
    with pytest.raises(ValueError, match="chunk"):
        next(episode_events(_run("tables"), chunk=0))


def test_serve_fleet_telemetry_subprocess():
    """`serve --fleet 4 --telemetry -` end to end: stdout carries a
    validatable JSONL event stream interleaved with the human log."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--fps", "2",
         "--duration", "3", "--fleet", "4", "--telemetry", "-"],
        capture_output=True, text=True, timeout=540, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    events = [validate_event(json.loads(ln))
              for ln in proc.stdout.splitlines()
              if ln.startswith("{")]
    kinds = [e["event"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert "steps" in kinds
    assert events[0]["n_cameras"] == 4
    assert events[-1]["metrics_summary"] is not None
