"""Examples run end-to-end as subprocesses (tiny env overrides).

The examples are the documented entry points and had zero coverage — an
API change that broke them (but not the library tests) would ship
silently. Each runs exactly as a user would invoke it, with the
REPRO_EX_* override hooks shrinking the scene/training so the whole
sweep stays CI-sized. Env is inherited so JAX_PLATFORMS=cpu survives
into the subprocess (no TPU-probe hangs).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXAMPLES = [
    ("quickstart.py", {"REPRO_EX_DURATION": "2.0"}, "MadEye"),
    ("adaptive_serving.py",
     {"REPRO_EX_DURATION": "2.0", "REPRO_EX_STEPS": "3"},
     "NN-in-the-loop MadEye accuracy"),
    ("continual_distillation.py",
     {"REPRO_EX_DURATION": "2.0", "REPRO_EX_EVALS": "4"},
     "replay: rank quality"),
    ("fleet_experiment.py",
     {"REPRO_EX_CAMERAS": "2", "REPRO_EX_STEPS": "3"},
     "fleet accuracy"),
]


def _run(cmd, env_overrides):
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.update(env_overrides)
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=900)


@pytest.mark.parametrize("script,overrides,marker", EXAMPLES,
                         ids=[e[0] for e in EXAMPLES])
def test_example_runs(script, overrides, marker):
    proc = _run([sys.executable, os.path.join(REPO, "examples", script)],
                overrides)
    assert proc.returncode == 0, \
        f"{script} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    assert marker in proc.stdout, \
        f"{script} did not reach its result line:\n{proc.stdout[-2000:]}"


def test_serve_unified_fleet_smoke():
    """The documented unified entry (`serve --fleet N --provider scene`)
    runs end to end: a 4-camera heterogeneous scene fleet through
    run_fleet(FleetRunSpec), exactly as a user would invoke it."""
    proc = _run([sys.executable, "-m", "repro.launch.serve",
                 "--fleet", "4", "--provider", "scene",
                 "--duration", "2", "--fps", "2"], {})
    assert proc.returncode == 0, \
        f"serve failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    line = next((ln for ln in proc.stdout.splitlines()
                 if ln.startswith("fleet x4") and "[scene]" in ln), None)
    assert line is not None and "acc=" in line, \
        f"no unified-fleet result line:\n{proc.stdout[-2000:]}"
