"""Examples run end-to-end as subprocesses (tiny env overrides).

The examples are the documented entry points and had zero coverage — an
API change that broke them (but not the library tests) would ship
silently. Each runs exactly as a user would invoke it, with the
REPRO_EX_* override hooks shrinking the scene/training so the whole
sweep stays CI-sized. Env is inherited so JAX_PLATFORMS=cpu survives
into the subprocess (no TPU-probe hangs).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXAMPLES = [
    ("quickstart.py", {"REPRO_EX_DURATION": "2.0"}, "MadEye"),
    ("adaptive_serving.py",
     {"REPRO_EX_DURATION": "2.0", "REPRO_EX_STEPS": "3"},
     "NN-in-the-loop MadEye accuracy"),
    ("continual_distillation.py",
     {"REPRO_EX_DURATION": "2.0", "REPRO_EX_EVALS": "4"},
     "replay: rank quality"),
]


@pytest.mark.parametrize("script,overrides,marker", EXAMPLES,
                         ids=[e[0] for e in EXAMPLES])
def test_example_runs(script, overrides, marker):
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.update(overrides)
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"{script} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    assert marker in proc.stdout, \
        f"{script} did not reach its result line:\n{proc.stdout[-2000:]}"
