"""Serving-pipeline integration tests on a small shared substrate."""
import numpy as np
import pytest

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.serving import (
    NetworkTrace,
    detection_tables,
    run_madeye,
    run_scheme,
    workload_acc_table,
)
from repro.serving.accuracy import query_acc_table
from repro.serving.teachers import TEACHERS, approx_observation, run_teacher

GRID = DEFAULT_GRID
WL = Workload((
    Query("yolov4", "person", "count"),
    Query("frcnn", "car", "detect"),
    Query("ssd", "person", "binary"),
    Query("tiny-yolov4", "person", "agg_count"),
))


@pytest.fixture(scope="module")
def substrate():
    video = build_video(GRID, SceneConfig(fps=15, seed=7), duration_s=10.0)
    tables = detection_tables(video, WL)
    acc = workload_acc_table(video, WL, tables)
    return video, tables, acc


# ---------------------------------------------------------------------------
# teachers
# ---------------------------------------------------------------------------

def test_teachers_are_deterministic(substrate):
    video, _, _ = substrate
    gt = dict(video.gt[5][12])
    gt["cell"] = 12
    a = run_teacher(TEACHERS["yolov4"], gt, 5, 0)
    b = run_teacher(TEACHERS["yolov4"], gt, 5, 0)
    np.testing.assert_array_equal(a["ids"], b["ids"])
    np.testing.assert_array_equal(a["boxes"], b["boxes"])


def test_teacher_bias_diversity(substrate):
    """Different teachers must diverge on the same scene (paper C2)."""
    video, _, _ = substrate
    totals = {}
    for name, prof in TEACHERS.items():
        n = 0
        for t in range(0, video.n_frames, 5):
            for c in range(GRID.n_cells):
                gt = dict(video.gt[t][c])
                gt["cell"] = c
                n += run_teacher(prof, gt, t, 0)["count"]
        totals[name] = n
    # the strong model sees strictly more than the weakest
    assert totals["frcnn"] > totals["tiny-yolov4"]
    assert len(set(totals.values())) > 1


def test_approx_degrades_teacher(substrate):
    video, tables, _ = substrate
    key = ("yolov4", "person")
    t_count = a_count = 0
    for t in range(video.n_frames):
        for c in range(GRID.n_cells):
            det = tables[key].dets[1.0][t][c]
            ap = approx_observation(det, miss_rate=0.3, seed_key=(t, c))
            t_count += det["count"]
            a_count += ap["count"]
    assert a_count < t_count
    assert a_count > 0.5 * t_count


# ---------------------------------------------------------------------------
# accuracy semantics
# ---------------------------------------------------------------------------

def test_acc_tables_in_unit_interval(substrate):
    video, tables, acc = substrate
    assert acc.shape == (video.n_frames, GRID.n_cells, 3)
    assert float(acc.min()) >= 0.0 and float(acc.max()) <= 1.0


def test_best_orientation_scores_one(substrate):
    """The relative metric: some orientation hits 1.0 whenever anything is
    detectable (count task)."""
    video, tables, _ = substrate
    qacc = query_acc_table(video, tables[("yolov4", "person")], "count")
    row_max = qacc.reshape(video.n_frames, -1).max(1)
    assert np.all(row_max >= 1.0 - 1e-9)


def test_oracle_ordering(substrate):
    """best_dynamic >= best_fixed >= one_time_fixed (oracle dominance)."""
    video, tables, acc = substrate
    b = BudgetConfig(fps=15)
    accs = {s: run_scheme(video, WL, tables, s, budget=b,
                          acc_table=acc).accuracy
            for s in ("one_time_fixed", "best_fixed", "best_dynamic")}
    assert accs["best_dynamic"] >= accs["best_fixed"] - 1e-9
    assert accs["best_fixed"] >= accs["one_time_fixed"] - 0.02


# ---------------------------------------------------------------------------
# MadEye end-to-end
# ---------------------------------------------------------------------------

def test_madeye_end_to_end(substrate):
    video, tables, acc = substrate
    trace = NetworkTrace.fixed(24, 20, video.n_frames)
    res = run_madeye(video, WL, tables, BudgetConfig(fps=5), trace,
                     acc_table=acc)
    assert 0.0 < res.accuracy <= 1.0
    assert res.mean_shape >= 1.0
    assert res.frames_sent >= len(res.visited)
    # every shipped orientation was actually explored that timestep
    for t, sent in res.visited.items():
        for (c, zi) in sent:
            assert c in res.explored[t]
            assert 0 <= zi < 3


def test_madeye_beats_one_time_fixed(substrate):
    video, tables, acc = substrate
    trace = NetworkTrace.fixed(24, 20, video.n_frames)
    m = run_madeye(video, WL, tables, BudgetConfig(fps=1), trace,
                   acc_table=acc)
    otf = run_scheme(video, WL, tables, "one_time_fixed",
                     budget=BudgetConfig(fps=1), acc_table=acc)
    assert m.accuracy > otf.accuracy - 0.02


def test_madeye_bounded_by_best_dynamic_plus_sends(substrate):
    video, tables, acc = substrate
    trace = NetworkTrace.fixed(24, 20, video.n_frames)
    m = run_madeye(video, WL, tables, BudgetConfig(fps=5), trace,
                   acc_table=acc)
    bd = run_scheme(video, WL, tables, "best_dynamic",
                    budget=BudgetConfig(fps=5), acc_table=acc)
    # MadEye ships k>=1 frames so it can exceed 1-frame best_dynamic only
    # via aggregate counting; give it that slack but keep a sane bound
    assert m.accuracy <= bd.accuracy + 0.15


def test_network_trace_affects_budget():
    t_fast = NetworkTrace.fixed(60, 5, 10)
    t_slow = NetworkTrace.fixed(6, 40, 10)
    assert t_fast.transfer_time(0, 25_000) < t_slow.transfer_time(0, 25_000)


def test_mobile_trace_has_fades():
    tr = NetworkTrace.mobile(2000, seed=1)
    assert tr.mbps.min() < 0.6 * tr.mbps.mean()
