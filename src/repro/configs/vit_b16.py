"""ViT-B/16 [arXiv:2010.11929; paper tier]."""
from repro.configs.base import VisionConfig, register

FULL = VisionConfig(
    name="vit-b16", img_res=224, patch=16, n_layers=12,
    d_model=768, n_heads=12, d_ff=3072,
)

SMOKE = VisionConfig(
    name="vit-b16-smoke", img_res=32, patch=8, n_layers=2,
    d_model=64, n_heads=4, d_ff=128, n_classes=10,
)

register(FULL, SMOKE)
