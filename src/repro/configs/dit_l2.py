"""DiT-L/2 [arXiv:2212.09748; paper tier].

img_res=256 (latent 32), patch=2, 24 layers, d_model=1024, 16 heads.
"""
from repro.configs.base import DiffusionConfig, register

FULL = DiffusionConfig(
    name="dit-l2",
    img_res=256,
    patch=2,
    latent_channels=4,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_classes=1000,
)

SMOKE = DiffusionConfig(
    name="dit-l2-smoke",
    img_res=32,
    patch=2,
    latent_channels=4,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_classes=10,
)

register(FULL, SMOKE)
