"""StableLM 3B [hf:stabilityai/stablelm family; unverified tier].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import LMConfig, register

FULL = LMConfig(
    name="stablelm-3b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab=50304,
    max_seq=524288,
    rope_theta=10000.0,
)

SMOKE = LMConfig(
    name="stablelm-3b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    max_seq=128,
)

register(FULL, SMOKE)
