"""MadEye approximation model (the paper's EfficientDet-D0 analogue,
TPU-native).

ViT-S-class backbone (frozen across queries, cached on cameras) + FPN-lite neck
+ anchor-free center/box/class heads (fine-tuned per query). ~4M
params to match
EfficientDet-D0's 3.9M budget.
"""
from repro.configs.base import DetectorConfig, register

FULL = DetectorConfig(
    name="madeye-approx",
    img_res=224,
    patch=16,
    n_layers=6,
    d_model=192,
    n_heads=6,
    d_ff=768,
    n_classes=2,
    max_boxes=32,
    fpn_dim=128,
)

SMOKE = DetectorConfig(
    name="madeye-approx-smoke",
    img_res=64,
    patch=16,
    n_layers=2,
    d_model=48,
    n_heads=3,
    d_ff=96,
    n_classes=2,
    max_boxes=8,
    fpn_dim=32,
)

register(FULL, SMOKE)
