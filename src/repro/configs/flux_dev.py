"""Flux-dev MMDiT rectified-flow [BFL tech report; unverified tier].

img_res=1024 latent_res=128 19 double blocks + 38 single blocks,
d_model=3072, 24 heads, ~12B params.
"""
from repro.configs.base import DiffusionConfig, register

FULL = DiffusionConfig(
    name="flux-dev",
    img_res=1024,
    latent_res=128,
    patch=2,
    latent_channels=16,
    n_double_blocks=19,
    n_single_blocks=38,
    d_model=3072,
    n_heads=24,
    cond_dim=4096,
)

SMOKE = DiffusionConfig(
    name="flux-dev-smoke",
    img_res=32,
    latent_res=8,
    patch=2,
    latent_channels=4,
    n_double_blocks=2,
    n_single_blocks=2,
    d_model=64,
    n_heads=4,
    cond_dim=32,
)

register(FULL, SMOKE)
