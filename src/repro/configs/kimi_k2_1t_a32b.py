"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2; unverified tier].

61L d_model=7168 64H (GQA kv=8) d_ff(moe per-expert)=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared), first layer dense (DeepSeek-V3-style stack).
"""
from repro.configs.base import LMConfig, register

FULL = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,              # dense-layer FFN width (first dense layer)
    vocab=163840,
    moe_experts=384,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=1,
    mla=False,               # K2 uses GQA-style attention w/ 64 heads, kv=8
    max_seq=524288,
    rope_theta=50000.0,
)

SMOKE = LMConfig(
    name="kimi-k2-1t-a32b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe_experts=8,
    moe_top_k=2,
    moe_shared_experts=1,
    moe_d_ff=32,
    first_dense_layers=1,
    max_seq=128,
)

register(FULL, SMOKE)
