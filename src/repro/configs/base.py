"""Config dataclasses + registry for all assigned architectures.

Every architecture file in this package registers:
  - its FULL config (exact paper/source numbers; exercised only via the
    dry-run with ShapeDtypeStruct — never allocated on CPU), and
  - a SMOKE config (same family, tiny dims) that runs a real forward/train
    step on CPU in the per-arch smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    max_seq: int = 8192
    rope_theta: float = 10000.0
    # MoE (None => dense)
    moe_experts: Optional[int] = None
    moe_top_k: int = 8
    moe_shared_experts: int = 0
    moe_d_ff: Optional[int] = None          # per-expert hidden dim
    first_dense_layers: int = 0     # e.g. deepseek: first k layers dense
    # MLA (None => GQA)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # numerics / schedule
    dtype: Any = jnp.bfloat16
    remat: bool = True
    tie_embeddings: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def family(self) -> str:
        return "lm"


@dataclass(frozen=True)
class VisionConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    # Swin-specific
    swin: bool = False
    window: int = 7
    depths: tuple = ()
    dims: tuple = ()
    dtype: Any = jnp.bfloat16
    remat: bool = False

    @property
    def family(self) -> str:
        return "vision"


@dataclass(frozen=True)
class DiffusionConfig:
    name: str
    img_res: int
    patch: int = 2
    latent_channels: int = 4
    n_layers: int = 0                # DiT
    n_double_blocks: int = 0         # MMDiT
    n_single_blocks: int = 0
    d_model: int = 1024
    n_heads: int = 16
    latent_res: Optional[int] = None  # flux operates on latents
    cond_dim: int = 768              # text/conditioning embedding width (stub)
    n_classes: int = 1000            # DiT class conditioning
    dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def family(self) -> str:
        return "diffusion"

    @property
    def is_mmdit(self) -> bool:
        return self.n_double_blocks > 0


@dataclass(frozen=True)
class DetectorConfig:
    """MadEye approximation model: light ViT backbone + anchor-free det
    heads."""
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 2               # {person, car}
    max_boxes: int = 32              # static box budget per frame
    fpn_dim: int = 128
    dtype: Any = jnp.float32
    remat: bool = False

    @property
    def family(self) -> str:
        return "detector"


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture family."""
    name: str
    kind: str         # train | prefill | decode | generate | serve
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Any] = {}
_SMOKE: dict[str, Any] = {}


def register(cfg, smoke=None):
    _REGISTRY[cfg.name] = cfg
    if smoke is not None:
        _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str):
    if name not in _REGISTRY:
        # import all config modules lazily on first miss
        import repro.configs  # noqa: F401  (triggers registration)
        from repro.configs import ALL_MODULES  # noqa: F401
    return _REGISTRY[name]


def get_smoke_config(name: str):
    if name not in _SMOKE:
        import repro.configs  # noqa: F401
    return _SMOKE[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
