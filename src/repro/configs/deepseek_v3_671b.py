"""DeepSeek-V3 671B [arXiv:2412.19437; hf tier].

61L d_model=7168 128H d_ff(per-expert)=2048 vocab=129280,
MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128),
MoE: 1 shared + 256 routed, top-8, first 3 layers dense (d_ff 18432).
"""
from repro.configs.base import LMConfig, register

FULL = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: all heads share the compressed latent
    head_dim=128,
    d_ff=18432,              # dense-layer FFN width (first 3 layers)
    vocab=129280,
    moe_experts=256,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    max_seq=524288,
    rope_theta=10000.0,
)

SMOKE = LMConfig(
    name="deepseek-v3-671b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe_experts=8,
    moe_top_k=2,
    moe_shared_experts=1,
    moe_d_ff=32,
    first_dense_layers=1,
    mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    max_seq=128,
)

register(FULL, SMOKE)
