"""Assigned per-family input-shape sets (40 cells total)."""
from __future__ import annotations

from repro.configs.base import ShapeSpec

LM_SHAPES = [
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
]

DIFFUSION_SHAPES = [
    ShapeSpec("train_256", "train", img_res=256, global_batch=256, steps=1000),
    ShapeSpec("gen_1024", "generate", img_res=1024, global_batch=4, steps=50),
    ShapeSpec("gen_fast", "generate", img_res=512, global_batch=16, steps=4),
    ShapeSpec("train_1024", "train", img_res=1024, global_batch=32,
              steps=1000),
]

VISION_SHAPES = [
    ShapeSpec("cls_224", "train", img_res=224, global_batch=256),
    ShapeSpec("cls_384", "train", img_res=384, global_batch=64),
    ShapeSpec("serve_b1", "serve", img_res=224, global_batch=1),
    ShapeSpec("serve_b128", "serve", img_res=224, global_batch=128),
]

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "diffusion": DIFFUSION_SHAPES,
    "vision": VISION_SHAPES,
}


def shapes_for(cfg) -> list[ShapeSpec]:
    return FAMILY_SHAPES[cfg.family]


def get_shape(cfg, shape_name: str) -> ShapeSpec:
    for s in shapes_for(cfg):
        if s.name == shape_name:
            return s
    raise KeyError(f"{shape_name} not a shape for family {cfg.family}")
