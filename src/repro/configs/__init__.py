"""Architecture configs. Importing this package registers all architectures."""
from repro.configs import (  # noqa: F401
    kimi_k2_1t_a32b,
    deepseek_v3_671b,
    stablelm_12b,
    stablelm_3b,
    flux_dev,
    dit_l2,
    vit_b16,
    swin_b,
    vit_h14,
    vit_s16,
    madeye_approx,
)
from repro.configs.base import (  # noqa: F401
    DetectorConfig,
    DiffusionConfig,
    LMConfig,
    ShapeSpec,
    VisionConfig,
    get_config,
    get_smoke_config,
    list_archs,
)
from repro.configs.shapes import (  # noqa: F401
    DIFFUSION_SHAPES,
    FAMILY_SHAPES,
    LM_SHAPES,
    VISION_SHAPES,
    get_shape,
    shapes_for,
)

ALL_MODULES = True

ASSIGNED_ARCHS = [
    "kimi-k2-1t-a32b",
    "deepseek-v3-671b",
    "stablelm-12b",
    "stablelm-3b",
    "flux-dev",
    "dit-l2",
    "vit-b16",
    "swin-b",
    "vit-h14",
    "vit-s16",
]
