"""StableLM-2 12B [hf:stabilityai/stablelm-2-12b family; hf tier].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.configs.base import LMConfig, register

FULL = LMConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    max_seq=524288,
    rope_theta=10000.0,
)

SMOKE = LMConfig(
    name="stablelm-12b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    max_seq=128,
)

register(FULL, SMOKE)
