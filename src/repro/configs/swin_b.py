"""Swin-B [arXiv:2103.14030; paper tier].

img_res=224 patch=4 window=7 depths=(2,2,18,2) dims=(128,256,512,1024).
"""
from repro.configs.base import VisionConfig, register

FULL = VisionConfig(
    name="swin-b", img_res=224, patch=4, n_layers=24,
    d_model=128, n_heads=4, d_ff=512, swin=True, window=7,
    depths=(2, 2, 18, 2), dims=(128, 256, 512, 1024),
)

SMOKE = VisionConfig(
    name="swin-b-smoke", img_res=32, patch=4, n_layers=4,
    d_model=16, n_heads=2, d_ff=64, swin=True, window=2,
    depths=(1, 1), dims=(16, 32), n_classes=10,
)

register(FULL, SMOKE)
