"""ViT-S/16 [arXiv:2010.11929; paper tier].

Also the backbone of MadEye's approximation-model detector
(configs/madeye_approx).
"""
from repro.configs.base import VisionConfig, register

FULL = VisionConfig(
    name="vit-s16", img_res=224, patch=16, n_layers=12,
    d_model=384, n_heads=6, d_ff=1536,
)

SMOKE = VisionConfig(
    name="vit-s16-smoke", img_res=32, patch=8, n_layers=2,
    d_model=48, n_heads=3, d_ff=96, n_classes=10,
)

register(FULL, SMOKE)
