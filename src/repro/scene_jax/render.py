"""Fixed-shape jnp rasterizer: SceneState boxes -> orientation crops.

Device port of `data/render.render_image` so the distilled approximation
model (models/detector) can score the *actual pixels* of every candidate
orientation inside the jit'd episode scan — the paper's camera-side
knowledge-distillation loop (§3.4) — instead of reading precomputed
teacher tables. Same image model as the numpy renderer: class-colored
object rectangles painted in slot order over a textured gradient
background, the FOV projection an axis-aligned crop in scene degrees.

Parity with `data/render.render_image` is exact at `noise=0` (pinned by
tests/test_render_jax.py): identical visibility rule (clipped area /
object area >= min_visible), identical pixel-bound rounding, identical
last-painter-wins overlap semantics, and the same multiplicative oid
shade computed in modular arithmetic so int32 never overflows. Noise is
the one deliberate divergence: the numpy path draws from a host
Generator, the device path from `jax.random` keyed as
fold_in(fold_in(camera_key, salt), frame) — per-camera decorrelated,
reproducible, and independent of fleet size or shard layout (the same
key discipline as the scene dynamics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.data.scene import PERSON

_RENDER_SALT = 0x9E4DE
# (oid * 2654435761) % 97 without the 64-bit product: reduce both factors
# mod 97 first (2654435761 % 97 == 75), exact for any non-negative oid
_SHADE_MULT_97 = 2654435761 % 97

_PERSON_COLOR = (0.9, 0.3, 0.2)
_CAR_COLOR = (0.2, 0.4, 0.9)


def render_background(res: int) -> jnp.ndarray:
    """[res, res, 3] textured gradient, identical to the numpy renderer."""
    yy, xx = jnp.meshgrid(jnp.arange(res, dtype=jnp.float32) / res,
                          jnp.arange(res, dtype=jnp.float32) / res,
                          indexing="ij")
    return jnp.stack([0.35 + 0.15 * yy, 0.4 + 0.1 * xx,
                      0.35 + 0.05 * (xx + yy)], axis=-1)


def render_noise(rng: jnp.ndarray, frame, res: int) -> jnp.ndarray:
    """Per-camera standard-normal noise images [F, res, res, 3] for one
    frame. rng [F, 2] camera keys; the render stream is salted so it
    never collides with the scene-dynamics stream derived from the same
    camera keys."""
    keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(rng, _RENDER_SALT)
    keys = jax.vmap(jax.random.fold_in)(keys, jnp.broadcast_to(
        frame, (rng.shape[0],)))
    return jax.vmap(lambda k: jax.random.normal(k, (res, res, 3)))(keys)


def object_colors(kind, oid) -> jnp.ndarray:
    """Per-object paint colors [..., M, 3]: class base color times the
    multiplicative oid shade, in modular arithmetic (identical to
    data/render.render_image). kind [M] (or broadcastable), oid [..., M].
    Shared by the jnp renderer and the fused kernels/crop_patchify path
    so the paint model has one definition."""
    shade = 0.7 + 0.3 * ((oid % 97) * _SHADE_MULT_97 % 97) / 97.0
    return jnp.where((kind == PERSON)[..., None],
                     jnp.asarray(_PERSON_COLOR),
                     jnp.asarray(_CAR_COLOR)) * shade[..., None]


def render_crop(pos, size, kind, oid, window, *, res: int = 64,
                min_visible: float = 0.25,
                noise_img: jnp.ndarray | None = None) -> jnp.ndarray:
    """One camera, one FOV window -> [res, res, 3] float32 in [0, 1].

    pos/size [M, 2] (scene degrees), kind/oid [M]; window (x0, y0, fw, fh)
    as in kernels.cell_rasterize.window_arrays. Disabled slots (size 0)
    have zero visibility and never paint. Boxes paint in slot order, so
    overlap resolution matches the numpy renderer's paint loop.
    """
    x0, y0, fw, fh = window[0], window[1], window[2], window[3]
    ox0 = pos[:, 0] - size[:, 0] / 2
    ox1 = pos[:, 0] + size[:, 0] / 2
    oy0 = pos[:, 1] - size[:, 1] / 2
    oy1 = pos[:, 1] + size[:, 1] / 2

    ix0 = jnp.maximum(ox0, x0)
    ix1 = jnp.minimum(ox1, x0 + fw)
    iy0 = jnp.maximum(oy0, y0)
    iy1 = jnp.minimum(oy1, y0 + fh)
    inter = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
    area = (ox1 - ox0) * (oy1 - oy0)
    keep = inter / jnp.maximum(area, 1e-9) >= min_visible

    # normalized clipped box -> pixel bounds, data/render's rounding:
    # clip first, then truncate (all values non-negative -> floor)
    bx0 = (ix0 - x0) / fw
    bx1 = (ix1 - x0) / fw
    by0 = (iy0 - y0) / fh
    by1 = (iy1 - y0) / fh
    px0 = jnp.clip(bx0 * res, 0, res - 1).astype(jnp.int32)
    px1 = jnp.clip(bx1 * res + 1, 1, res).astype(jnp.int32)
    py0 = jnp.clip(by0 * res, 0, res - 1).astype(jnp.int32)
    py1 = jnp.clip(by1 * res + 1, 1, res).astype(jnp.int32)

    color = object_colors(kind, oid)                              # [M, 3]

    img = render_background(res)
    if noise_img is not None:
        img = img + noise_img
    rr = jnp.arange(res)[None, :, None]         # rows (y)
    cc = jnp.arange(res)[None, None, :]         # cols (x)

    # the numpy renderer paints boxes sequentially in slot order, so the
    # highest-index covering box owns each pixel — one masked argmax
    # instead of M sequential paints
    hit = (keep[:, None, None]
           & (rr >= py0[:, None, None]) & (rr < py1[:, None, None])
           & (cc >= px0[:, None, None]) & (cc < px1[:, None, None]))
    m_idx = jnp.arange(pos.shape[0])[:, None, None]
    m_best = jnp.max(jnp.where(hit, m_idx, -1), axis=0)      # [res, res]
    img = jnp.where((m_best >= 0)[..., None],
                    color[jnp.maximum(m_best, 0)], img)
    return jnp.clip(img, 0.0, 1.0)


@partial(jax.jit,
         static_argnames=("res", "min_visible"))
def render_fleet_crops(pos, size, kind, oid, windows, *, res: int = 64,
                       min_visible: float = 0.25,
                       noise: jnp.ndarray | None = None) -> jnp.ndarray:
    """The whole fleet's candidate-orientation crops in one pass.

    pos/size [F, M, 2], kind [M] (slot layout is fleet-wide: scene_jax
    .kind_mask), oid [F, M], windows [C, 4] fleet-shared or [F, C, 4]
    per camera (the candidate-sparse shortlist gathers a different
    window set per camera), noise [F, res, res, 3] or None (one noise
    image per camera per frame, shared across windows — data/render
    seeds its Generator per frame, so its noise is likewise shared
    across the crops of one snapshot). Returns [F, C, res, res, 3].
    """
    per_window = jax.vmap(
        lambda p, s, o, w, nz: render_crop(
            p, s, kind, o, w, res=res, min_visible=min_visible,
            noise_img=nz),
        in_axes=(None, None, None, 0, None))
    win_ax = None if windows.ndim == 2 else 0
    per_cam = jax.vmap(per_window, in_axes=(0, 0, 0, win_ax, 0))
    if noise is None:
        noise = jnp.zeros((pos.shape[0], res, res, 3))
    return per_cam(pos, size, oid, windows, noise)
