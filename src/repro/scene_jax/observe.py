"""Scene state -> per-(cell, zoom, pair) observations, fully on device.

`observe_all_cells` is the device-resident analogue of what the host
pipeline assembles from `gt_boxes` + `run_teacher` + `approx_observation`
when it materializes `EpisodeTables`: for every camera it produces the
approximation-model counts/areas per (cell, zoom, pair), the box-geometry
summaries the zoom controller reads (centroid / spread / extent / nbox),
and the oracle workload accuracy used as backend feedback.

Teacher model (deterministic, like serving.teachers but hash-native JAX):
detection probability is the same saturating ramp of apparent size with
per-(model, class) quirked thresholds and the same base+bucket flicker
mix; the uniform draw is an FNV-style integer hash of (object id, pair,
bucket), so detections flicker on the paper's timescale and are exactly
reproducible. The approximation model applies an extra per-(object, step)
miss on top (`miss_rate`). Two deliberate simplifications vs the host
teachers, pinned by the scene-vs-tables parity tests rather than the
numpy-substrate ones: no localization noise / false positives (geometry
is exact), and `spread` is the RMS box-center distance (one-pass moment)
instead of the mean distance.

Oracle accuracy: per query, relative accuracy of TEACHER counts across
orientations (binary -> any-detection; count/agg/detect -> count over the
per-step max; the detect task's recall x quality score reduces to the
count ratio here because identity recall is proportional to the count and
quality is 1 without localization noise).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.cell_rasterize.ops import cell_rasterize, window_arrays
from repro.scene_jax.scene import SceneFleetParams, SceneSpec, SceneState, \
    kind_mask
from repro.serving.teachers import TEACHERS

_MISS_SALT = 0x4D155
_BASE_SALT = 0xBA5E


class TeacherArrays(NamedTuple):
    """Per-pair teacher response constants for one workload (device)."""
    a0: jnp.ndarray         # [P] quirked apparent-size floor
    a1: jnp.ndarray         # [P] quirked saturation size
    pmax: jnp.ndarray       # [P] plateau detection probability
    flicker: jnp.ndarray    # [P] bucket-hash mix weight
    cls: jnp.ndarray        # [P] object class (PERSON/CAR)
    salt: jnp.ndarray       # [P] stable per-pair hash salt


def teacher_arrays(pairs) -> TeacherArrays:
    """pairs: WorkloadSpec.pairs — ((model, obj), ...) in table order."""
    from repro.data.dataset import OBJ_IDS

    a0, a1, pmax, flick, cls, salt = [], [], [], [], [], []
    for model, obj in pairs:
        prof = TEACHERS[model]
        c = OBJ_IDS[obj]
        q = prof.class_quirk(c)
        a0.append(prof.a_min * q)
        a1.append(prof.a_sat * q)
        pmax.append(prof.p_max)
        flick.append(prof.flicker)
        cls.append(c)
        salt.append(_fnv_host(model, obj))
    return TeacherArrays(
        a0=jnp.asarray(a0, jnp.float32), a1=jnp.asarray(a1, jnp.float32),
        pmax=jnp.asarray(pmax, jnp.float32),
        flicker=jnp.asarray(flick, jnp.float32),
        cls=jnp.asarray(cls, jnp.int32),
        salt=jnp.asarray(salt, jnp.uint32))


def _fnv_host(*keys) -> int:
    """Stable 32-bit FNV-1a of the stringified keys (host side)."""
    h = 2166136261
    for b in "|".join(map(str, keys)).encode():
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h


def hash01(*ints) -> jnp.ndarray:
    """Stable uniform [0, 1) from broadcastable integer arrays — the JAX
    analogue of serving.teachers._hash01 (per-key mixing, xxhash-style
    avalanche), shared by the flicker draws and the approx-miss draws."""
    h = jnp.uint32(0x811C9DC5)
    for x in ints:
        h = h ^ jnp.asarray(x).astype(jnp.uint32)
        h = h * jnp.uint32(0x9E3779B1)
        h = h ^ (h >> 15)
        h = h * jnp.uint32(0x85EBCA77)
        h = h ^ (h >> 13)
    return h.astype(jnp.float32) * jnp.float32(2.0 ** -32)


class SceneObs(NamedTuple):
    """Per-camera observation tables; leaves lead with [F, N, Z]."""
    counts: jnp.ndarray     # [F, N, Z, P]
    areas: jnp.ndarray      # [F, N, Z, P]
    centroid: jnp.ndarray   # [F, N, Z, 2]
    spread: jnp.ndarray     # [F, N, Z]
    extent: jnp.ndarray     # [F, N, Z]
    nbox: jnp.ndarray       # [F, N, Z] int32
    acc_true: jnp.ndarray   # [F, N, Z]


def grid_windows(grid, zoom_levels=(1.0, 2.0, 3.0)) -> jnp.ndarray:
    """Device copy of the flattened (cell x zoom) FOV windows."""
    return jnp.asarray(window_arrays(grid, zoom_levels))


def detections_obs(dets, windows: jnp.ndarray, pair_cls: jnp.ndarray,
                   thresh: jnp.ndarray, geo_thresh: jnp.ndarray,
                   acc_true: jnp.ndarray, *, n_zoom: int = 3) -> SceneObs:
    """Distilled-detector outputs -> the same observation tables the
    oracle pass produces, so `fleet_step` consumes either interchangeably.

    dets: models.detector.Detections with leaves [F, C, K, ...] — one row
    per (camera, flattened cell x zoom window); windows [C, 4] the
    matching FOV windows (cell-major, kernels.cell_rasterize
    .window_arrays layout); pair_cls [P] object class per workload pair;
    thresh [P] per-pair score threshold (a detection counts for pair p
    when its score clears thresh[p] AND its argmax class is pair p's
    object); geo_thresh [] score floor for the zoom-geometry statistics.
    acc_true [F, N, Z] rides through untouched — backend feedback stays
    the oracle's judgment of what the camera chose, only the camera-side
    ranking signal switches to the approximation model (paper §3.4).

    Boxes arrive in normalized image coordinates; geometry converts to
    scene degrees through the per-window FOV transform (data/render
    .boxes_to_scene) because the zoom controller compares centroids and
    spreads against cell centers in degrees. Counts are float32 like the
    rasterized tables; `spread` is the same one-pass RMS moment.
    """
    f, c, k = dets.scores.shape
    n = c // n_zoom
    x0 = windows[:, 0][None, :, None]           # [1, C, 1]
    y0 = windows[:, 1][None, :, None]
    fw = windows[:, 2][None, :, None]
    fh = windows[:, 3][None, :, None]
    deg_x = x0 + dets.boxes[..., 0] * fw        # [F, C, K]
    deg_y = y0 + dets.boxes[..., 1] * fh
    w_img, h_img = dets.boxes[..., 2], dets.boxes[..., 3]

    cls_id = jnp.argmax(dets.class_probs, axis=-1)          # [F, C, K]
    keep_p = ((dets.scores[:, :, None, :] >= thresh[None, None, :, None])
              & (cls_id[:, :, None, :]
                 == pair_cls[None, None, :, None]))         # [F, C, P, K]
    kf = keep_p.astype(jnp.float32)
    counts = jnp.sum(kf, axis=-1)                           # [F, C, P]
    areas = jnp.sum(kf * (w_img * h_img)[:, :, None, :], axis=-1)

    geo = (dets.scores >= geo_thresh).astype(jnp.float32)   # [F, C, K]
    nbox = jnp.sum(geo, axis=-1)                            # [F, C]
    nb = jnp.maximum(nbox, 1e-9)
    cx = jnp.sum(geo * deg_x, axis=-1) / nb
    cy = jnp.sum(geo * deg_y, axis=-1) / nb
    c2 = jnp.sum(geo * (deg_x * deg_x + deg_y * deg_y), axis=-1) / nb
    has = nbox > 0
    centroid = jnp.where(has[..., None], jnp.stack([cx, cy], -1), 0.0)
    spread = jnp.where(has, jnp.sqrt(jnp.maximum(
        c2 - cx * cx - cy * cy, 0.0)), 0.0)
    side = jnp.maximum(w_img * fw, h_img * fh)
    extent = jnp.max(jnp.where(geo > 0, side, 0.0), axis=-1)

    def to_nz(x):           # [F, C, ...] -> [F, N, Z, ...]
        return x.reshape((f, n, n_zoom) + x.shape[2:])

    return SceneObs(counts=to_nz(counts), areas=to_nz(areas),
                    centroid=to_nz(centroid), spread=to_nz(spread),
                    extent=to_nz(extent),
                    nbox=to_nz(nbox).astype(jnp.int32), acc_true=acc_true)


@partial(jax.jit, static_argnames=("spec", "task_id", "pair_idx", "n_zoom"))
def observe_all_cells(spec: SceneSpec, teach: TeacherArrays,
                      params: SceneFleetParams, state: SceneState,
                      t: jnp.ndarray, windows: jnp.ndarray, *,
                      task_id: tuple, pair_idx: tuple, n_zoom: int = 3,
                      cam_salt: jnp.ndarray | None = None) -> SceneObs:
    """One observation pass for the whole fleet at controller frame `t`
    ([F] int32, the flicker/miss clock). windows [N*Z, 4] from
    `grid_windows`; task_id/pair_idx from WorkloadSpec. cam_salt [F]
    (any stable per-camera int, e.g. a word of the camera's key)
    decorrelates detection/miss noise across cameras — without it,
    object slot k draws identical teacher noise on every camera."""
    f, m = state.oid.shape
    p = teach.a0.shape[0]
    kinds = jnp.asarray(kind_mask(spec))
    cls_match = (teach.cls[:, None] == kinds[None, :])     # [P, M]

    if cam_salt is None:
        cam_salt = jnp.zeros(f, jnp.uint32)
    cam = cam_salt[:, None, None]                          # [F, 1, 1]
    oid = state.oid[:, None, :]                            # [F, 1, M]
    salt = teach.salt[None, :, None]                       # [1, P, 1]
    bucket = (t // spec.flicker_bucket)[:, None, None]     # [F, 1, 1]
    draw = ((1.0 - teach.flicker[None, :, None])
            * hash01(oid, salt, cam, jnp.uint32(_BASE_SALT))
            + teach.flicker[None, :, None] * hash01(oid, salt, cam, bucket))
    # normalize by the plateau so the kernel's ramp test draw < resp
    # reproduces draw < p_max * resp
    draw = draw / jnp.maximum(teach.pmax[None, :, None], 1e-6)
    live = params.enabled[:, None, :] & cls_match[None]    # [F, P, M]
    keep = hash01(state.oid, t[:, None], cam_salt[:, None],
                  jnp.uint32(_MISS_SALT)) >= spec.miss_rate  # [F, M]
    draw_student = jnp.where(live & keep[:, None, :], draw, 2.0)
    draw_teacher = jnp.where(live, draw, 2.0)

    ox, oy = state.pos[..., 0], state.pos[..., 1]
    ow, oh = state.size[..., 0], state.size[..., 1]
    # one rasterization pass: teacher draws stack as extra count-only
    # channels [F, 2P, M] (n_moment=P keeps the geometry student-driven),
    # so the per-(object, window) clipping/visibility work is not doubled
    cnt2, area2, wcx, wcy, wc2, ext = cell_rasterize(
        ox, oy, ow, oh, jnp.concatenate([draw_student, draw_teacher], 1),
        a0=jnp.tile(teach.a0, 2), a1=jnp.tile(teach.a1, 2),
        windows=windows, min_visible=spec.min_visible, n_moment=p,
        use_kernel=spec.use_kernel, interpret=spec.kernel_interpret)
    cnt, area = cnt2[:, :p], area2[:, :p]
    cnt_t = cnt2[:, p:]

    n = windows.shape[0] // n_zoom

    def to_nz(x):           # [F, P, C] -> [F, N, Z, P]
        return jnp.transpose(x.reshape(f, p, n, n_zoom), (0, 2, 3, 1))

    counts = to_nz(cnt)
    areas = to_nz(area)
    nbox = jnp.sum(cnt, axis=1).reshape(f, n, n_zoom)
    nb = jnp.maximum(nbox, 1e-9)
    cx = (wcx / nb.reshape(f, -1)).reshape(f, n, n_zoom)
    cy = (wcy / nb.reshape(f, -1)).reshape(f, n, n_zoom)
    has = nbox > 0
    centroid = jnp.where(has[..., None],
                         jnp.stack([cx, cy], -1), 0.0)
    spread = jnp.where(has, jnp.sqrt(jnp.maximum(
        wc2.reshape(f, n, n_zoom) / nb - cx * cx - cy * cy, 0.0)), 0.0)
    extent = ext.reshape(f, n, n_zoom)

    # oracle workload accuracy from teacher counts (relative per step)
    acc = None
    for q in range(len(pair_idx)):
        c_q = cnt_t[:, pair_idx[q], :]                     # [F, C]
        mx = jnp.max(c_q, axis=-1, keepdims=True)
        if task_id[q] == 0:       # binary: correct "no" when scene empty
            a = jnp.where(mx > 0, (c_q > 0).astype(jnp.float32), 1.0)
        else:                     # count / detect / agg_count
            a = jnp.where(mx > 0, c_q / jnp.maximum(mx, 1e-9), 1.0)
        acc = a if acc is None else acc + a
    acc_true = (acc / len(pair_idx)).reshape(f, n, n_zoom)

    return SceneObs(counts=counts, areas=areas, centroid=centroid,
                    spread=spread, extent=extent,
                    nbox=nbox.astype(jnp.int32), acc_true=acc_true)
