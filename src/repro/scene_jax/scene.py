"""Device-resident procedural scene: fixed-shape JAX port of data/scene.py.

The numpy `Scene` is a stateful per-object Python loop — fine for building
offline tables, but it pins episode length to host materialization and
forces every camera in a fleet to watch the same world. This module keeps
the same dynamics (POI random-walk people, lane-traffic cars, churn
respawn, stationary density) as pure functions over a `SceneState` pytree
whose leaves lead with a fleet axis [F, max_objects], so a heterogeneous
fleet's scenes advance *inside* the jit'd episode scan:

  * `SceneSpec`        — hashable compile-time constants (extent, slot
                         layout, spawn size ranges, teacher-noise knobs);
  * `SceneFleetParams` — per-camera arrays (speeds, churn, POI layout,
                         density via the `enabled` slot mask) so cameras
                         differ without retracing;
  * `scene_step`       — one frame for the whole fleet, driven by
                         per-camera `jax.random` keys derived as
                         fold_in(camera_key, frame) — reproducible and
                         independent of fleet size or shard layout.

Object identity (`oid`) survives respawns exactly like the numpy scene:
a respawned slot takes the camera's next fresh id, which is what the
aggregate-counting metrics and the flicker-deterministic teachers key on.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.scene import CAR, PERSON, SceneConfig

_POI_SALT = 0x5CE7E


@dataclass(frozen=True)
class SceneSpec:
    """Static scene layout — everything jit treats as compile-time.

    Slot layout is fixed: slots [0, max_people) are people, the rest cars;
    per-camera density is the `enabled` mask in SceneFleetParams, so a
    sparse camera and a dense camera share one compiled program."""
    extent: tuple = (150.0, 75.0)
    fps: int = 15
    max_people: int = 14
    max_cars: int = 8
    n_poi: int = 3
    person_size: tuple = (2.5, 5.5)
    car_size: tuple = (5.0, 9.0)
    lane_tilts: tuple = (20.0, 32.0, 44.0)
    # observation model (mirrors serving.teachers / pipeline defaults)
    min_visible: float = 0.25
    miss_rate: float = 0.12
    flicker: float = 0.4
    flicker_bucket: int = 3
    # cell_rasterize dispatch (same semantics as FleetConfig.use_kernel)
    use_kernel: bool = False
    kernel_interpret: bool = True

    @property
    def max_objects(self) -> int:
        return self.max_people + self.max_cars

    @classmethod
    def from_config(cls, cfg: SceneConfig, **overrides) -> "SceneSpec":
        """Geometry/layout of a numpy SceneConfig as a static spec.

        Dynamics (person_speed, car_speed, churn) are per-camera ARRAYS
        in SceneFleetParams, not spec fields — use `fleet_from_config`
        to port a full SceneConfig including its dynamics."""
        kw = dict(extent=tuple(cfg.extent), fps=cfg.fps,
                  max_people=cfg.n_people, max_cars=cfg.n_cars,
                  n_poi=cfg.n_poi, person_size=tuple(cfg.person_size),
                  car_size=tuple(cfg.car_size),
                  lane_tilts=tuple(cfg.lane_tilts))
        kw.update(overrides)
        return cls(**kw)


class SceneFleetParams(NamedTuple):
    """Per-camera scene heterogeneity; every leaf leads with [F]."""
    person_speed: jnp.ndarray   # [F] deg/s mean
    car_speed: jnp.ndarray      # [F] deg/s mean
    churn: jnp.ndarray          # [F] per-step respawn probability
    poi: jnp.ndarray            # [F, n_poi, 2] person points-of-interest
    enabled: jnp.ndarray        # [F, M] bool — density (live slots)


class SceneState(NamedTuple):
    """Struct-of-arrays object state; leaves lead with [F, M]."""
    pos: jnp.ndarray            # [F, M, 2] degrees
    vel: jnp.ndarray            # [F, M, 2] deg/s
    size: jnp.ndarray           # [F, M, 2] degrees (w, h)
    waypoint: jnp.ndarray       # [F, M, 2] person targets
    oid: jnp.ndarray            # [F, M] int32 unique-per-camera ids
    next_id: jnp.ndarray        # [F] int32


def kind_mask(spec: SceneSpec) -> np.ndarray:
    """[M] int — PERSON for the first max_people slots, CAR after."""
    return np.where(np.arange(spec.max_objects) < spec.max_people,
                    PERSON, CAR)


def scene_fleet_params(spec: SceneSpec, n_cameras: int, *, seed: int = 0,
                       scene_seeds=None, person_speed=1.2, car_speed=10.0,
                       churn=0.01, n_people=None, n_cars=None
                       ) -> tuple[SceneFleetParams, jnp.ndarray]:
    """Build per-camera params + camera PRNG keys.

    Every scalar argument broadcasts; pass an [F] array for heterogeneity.
    Camera f's key is fold_in(PRNGKey(seed), scene_seeds[f]) — two fleets
    that share (seed, scene_seeds[f]) produce identical scenes for that
    camera regardless of fleet size or shard layout.
    """
    f, m = n_cameras, spec.max_objects
    if scene_seeds is None:
        scene_seeds = np.arange(f)
    scene_seeds = jnp.asarray(np.broadcast_to(scene_seeds, (f,)), jnp.int32)
    rng = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(seed), scene_seeds)

    def bc(x):
        return jnp.asarray(np.broadcast_to(np.asarray(x, np.float32), (f,)))

    n_people = spec.max_people if n_people is None else n_people
    n_cars = spec.max_cars if n_cars is None else n_cars
    n_people = np.broadcast_to(np.asarray(n_people, np.int32), (f,))
    n_cars = np.broadcast_to(np.asarray(n_cars, np.int32), (f,))
    if (n_people > spec.max_people).any() or (n_cars > spec.max_cars).any():
        raise ValueError("per-camera n_people/n_cars exceed SceneSpec slots")
    idx = np.arange(m)
    enabled = np.where(idx[None, :] < spec.max_people,
                       idx[None, :] < n_people[:, None],
                       (idx[None, :] - spec.max_people) < n_cars[:, None])

    poi_keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
        rng, _POI_SALT)
    lo = jnp.array([15.0, 10.0])
    hi = jnp.array([spec.extent[0] - 15.0, spec.extent[1] - 10.0])
    poi = jax.vmap(lambda k: jax.random.uniform(
        k, (spec.n_poi, 2), minval=lo, maxval=hi))(poi_keys)

    params = SceneFleetParams(
        person_speed=bc(person_speed), car_speed=bc(car_speed),
        churn=bc(churn), poi=poi, enabled=jnp.asarray(enabled))
    return params, rng


def fleet_from_config(cfg: SceneConfig, n_cameras: int, *, seed: int = 0,
                      scene_seeds=None, **spec_overrides
                      ) -> tuple[SceneSpec, SceneFleetParams, jnp.ndarray]:
    """Port one numpy SceneConfig — geometry AND dynamics — to the fleet
    substrate: (SceneSpec, homogeneous SceneFleetParams, camera keys)."""
    spec = SceneSpec.from_config(cfg, **spec_overrides)
    params, rng = scene_fleet_params(
        spec, n_cameras, seed=seed, scene_seeds=scene_seeds,
        person_speed=cfg.person_speed, car_speed=cfg.car_speed,
        churn=cfg.churn)
    return spec, params, rng


# ---------------------------------------------------------------------------
# spawn / step (single camera; vmapped over the fleet axis)
# ---------------------------------------------------------------------------

def _norm(v, axis=-1, keepdims=True):
    return jnp.sqrt(jnp.sum(v * v, axis=axis, keepdims=keepdims))


def _spawn_draws(spec: SceneSpec, p, key):
    """All per-slot respawn draws for one camera -> dict of [M, ...]."""
    m = spec.max_objects
    ks = jax.random.split(key, 8)
    extent = jnp.asarray(spec.extent)
    # person draws
    poi_a = p.poi[jax.random.randint(ks[0], (m,), 0, spec.n_poi)]
    pos_p = jnp.clip(poi_a + 8.0 * jax.random.normal(ks[1], (m, 2)),
                     jnp.array([1.0, 1.0]), extent - 1.0)
    wp_p = p.poi[jax.random.randint(ks[2], (m,), 0, spec.n_poi)]
    speed_p = jnp.maximum(
        0.2, p.person_speed + 0.4 * jax.random.normal(ks[3], (m,)))
    d = wp_p - pos_p
    vel_p = speed_p[:, None] * d / jnp.maximum(_norm(d), 1e-6)
    w_p = jax.random.uniform(ks[4], (m,), minval=spec.person_size[0],
                             maxval=spec.person_size[1])
    size_p = jnp.stack([w_p * 0.45, w_p], -1)
    # car draws
    lanes = jnp.asarray(spec.lane_tilts)
    lane = lanes[jax.random.randint(ks[5], (m,), 0, len(spec.lane_tilts))]
    u = jax.random.uniform(ks[6], (m, 4))
    direction = jnp.where(u[:, 0] < 0.5, -1.0, 1.0)
    x0 = jnp.where(direction > 0, 0.0, spec.extent[0])
    x0_init = u[:, 1] * spec.extent[0]          # initial=True placement
    tilt = lane + (u[:, 2] - 0.5) * 2.0 * 1.73  # ~N(0,1) spread, uniform
    speed_c = jnp.maximum(
        2.0, p.car_speed + 2.5 * jax.random.normal(ks[7], (m,)))
    vel_c = jnp.stack([direction * speed_c, jnp.zeros_like(speed_c)], -1)
    w_c = spec.car_size[0] + u[:, 3] * (spec.car_size[1] - spec.car_size[0])
    size_c = jnp.stack([w_c, w_c * 0.45], -1)
    return dict(pos_p=pos_p, wp_p=wp_p, vel_p=vel_p, size_p=size_p,
                x0=x0, x0_init=x0_init, tilt=tilt, vel_c=vel_c,
                size_c=size_c)


def _init_one(spec: SceneSpec, p: SceneFleetParams, key) -> SceneState:
    m = spec.max_objects
    person = jnp.asarray(kind_mask(spec) == PERSON)
    d = _spawn_draws(spec, p, key)
    pos = jnp.where(person[:, None], d["pos_p"],
                    jnp.stack([d["x0_init"], d["tilt"]], -1))
    vel = jnp.where(person[:, None], d["vel_p"], d["vel_c"])
    size = jnp.where(person[:, None], d["size_p"], d["size_c"])
    # disabled slots park far outside with zero size: never visible
    off = ~p.enabled
    pos = jnp.where(off[:, None], -1000.0, pos)
    vel = jnp.where(off[:, None], 0.0, vel)
    size = jnp.where(off[:, None], 0.0, size)
    return SceneState(pos=pos, vel=vel, size=size, waypoint=d["wp_p"],
                      oid=jnp.arange(m, dtype=jnp.int32),
                      next_id=jnp.asarray(m, jnp.int32))


def _step_one(spec: SceneSpec, p: SceneFleetParams, key,
              s: SceneState) -> SceneState:
    m = spec.max_objects
    person = jnp.asarray(kind_mask(spec) == PERSON)
    extent = jnp.asarray(spec.extent)
    dt = 1.0 / spec.fps
    k_wp, k_jit, k_churn, k_spawn = jax.random.split(key, 4)

    pos = s.pos + s.vel * dt

    # people: retarget near waypoints, jitter heading, stay in bounds
    d = s.waypoint - pos
    arrived = _norm(d, keepdims=False) < 2.0
    kw1, kw2 = jax.random.split(k_wp)
    new_wp = p.poi[jax.random.randint(kw1, (m,), 0, spec.n_poi)] \
        + 6.0 * jax.random.normal(kw2, (m, 2))
    waypoint = jnp.where((person & arrived)[:, None], new_wp, s.waypoint)
    d = waypoint - pos
    speed = _norm(s.vel)
    v = speed * d / jnp.maximum(_norm(d), 1e-6) \
        + 0.3 * jax.random.normal(k_jit, (m, 2))
    vel_pn = v / jnp.maximum(_norm(v), 1e-6) * speed
    pos_pn = jnp.clip(pos, 0.0, extent)
    vel = jnp.where(person[:, None], vel_pn, s.vel)
    pos = jnp.where(person[:, None], pos_pn, pos)

    # respawn: person churn + cars leaving the panorama
    churn = person & (jax.random.uniform(k_churn, (m,))
                      < p.churn * dt * spec.fps)
    out = ~person & ((pos[:, 0] < -3.0) | (pos[:, 0] > spec.extent[0] + 3.0))
    respawn = (churn | out) & p.enabled

    sd = _spawn_draws(spec, p, k_spawn)
    sp_pos = jnp.where(person[:, None], sd["pos_p"],
                       jnp.stack([sd["x0"], sd["tilt"]], -1))
    sp_vel = jnp.where(person[:, None], sd["vel_p"], sd["vel_c"])
    sp_size = jnp.where(person[:, None], sd["size_p"], sd["size_c"])

    pos = jnp.where(respawn[:, None], sp_pos, pos)
    vel = jnp.where(respawn[:, None], sp_vel, vel)
    size = jnp.where(respawn[:, None], sp_size, s.size)
    waypoint = jnp.where(respawn[:, None], sd["wp_p"], waypoint)
    new_ids = s.next_id + jnp.cumsum(respawn.astype(jnp.int32)) - 1
    oid = jnp.where(respawn, new_ids, s.oid)
    next_id = s.next_id + jnp.sum(respawn, dtype=jnp.int32)
    return SceneState(pos=pos, vel=vel, size=size, waypoint=waypoint,
                      oid=oid, next_id=next_id)


@partial(jax.jit, static_argnames=("spec",))
def init_scene(spec: SceneSpec, params: SceneFleetParams,
               rng: jnp.ndarray) -> SceneState:
    """Initial spawn for the whole fleet. rng [F, 2] camera keys."""
    return jax.vmap(partial(_init_one, spec))(params, rng)


@partial(jax.jit, static_argnames=("spec",))
def scene_step(spec: SceneSpec, params: SceneFleetParams, keys: jnp.ndarray,
               state: SceneState) -> SceneState:
    """Advance every camera's scene one frame. keys [F, 2] per-step keys
    (derive as vmap(fold_in)(camera_rng, frame_index) so replays and
    host-materialized tables see the identical stream)."""
    return jax.vmap(partial(_step_one, spec))(params, keys, state)


def advance_scene(spec: SceneSpec, params: SceneFleetParams,
                  rng: jnp.ndarray, state: SceneState, step_idx,
                  stride: int) -> SceneState:
    """Advance `stride` scene frames for controller step `step_idx` —
    the scene runs at spec.fps while the controller runs at the response
    rate, exactly like run_madeye's frame stride. step_idx may be [F]."""
    step_idx = jnp.broadcast_to(step_idx, rng.shape[:1])
    for j in range(stride):
        frame = step_idx * stride + j
        keys = jax.vmap(jax.random.fold_in)(rng, frame)
        state = scene_step(spec, params, keys, state)
    return state
