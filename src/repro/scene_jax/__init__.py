"""Device-resident scene substrate — the procedural simulator
(data/scene.py) as pure-JAX fixed-shape dynamics so heterogeneous fleet
episodes generate their observations *inside* the jit'd episode scan
instead of scanning host-precomputed tables.

  scene.py    SceneSpec / SceneFleetParams / SceneState pytrees,
              init_scene + scene_step + advance_scene fleet dynamics
  observe.py  scene state -> per-(cell, zoom, pair) counts/areas/geometry
              + oracle accuracy (FleetObs substrate), dispatching the hot
              boxes -> cells aggregation to kernels/cell_rasterize; also
              detections_obs, the distilled-detector analogue of the same
              tables (models/detector outputs -> FleetObs substrate)
  render.py   SceneState boxes -> per-orientation image crops, the jnp
              port of data/render.render_image the in-scan approximation
              model scores (paper §3.4's camera-side distillation loop)
"""
from repro.scene_jax.scene import (
    SceneFleetParams,
    SceneSpec,
    SceneState,
    advance_scene,
    fleet_from_config,
    init_scene,
    scene_fleet_params,
    scene_step,
)
from repro.scene_jax.observe import (
    SceneObs,
    TeacherArrays,
    detections_obs,
    grid_windows,
    hash01,
    observe_all_cells,
    teacher_arrays,
)
from repro.scene_jax.render import (
    render_background,
    render_crop,
    render_fleet_crops,
    render_noise,
)
