"""Zoom controller (paper §3.3 "Handling zoom").

Past accuracies can't reveal what a different zoom would have seen, so the
controller is driven by bbox geometry from the approximation models:

  * a cell newly added to the shape starts at the lowest zoom (full
    visibility);
  * per timestep, the mean distance of each box to the bbox centroid is
    compared against the area covered by each zoom factor — tight clusters
    are safe to zoom into;
  * cells auto-zoom out after `zoom_out_after` seconds (default 3 s per
    the paper) so newly entering objects aren't missed.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grid import OrientationGrid


@dataclass
class ZoomConfig:
    zoom_levels: tuple = (1.0, 2.0, 3.0)
    zoom_out_after: float = 3.0      # seconds
    margin: float = 0.7              # cluster must fit in margin * FOV/2


@dataclass
class ZoomState:
    zoom_idx: np.ndarray             # [n_cells] int — index into zoom_levels
    zoomed_since: np.ndarray         # [n_cells] float — seconds at > min zoom

    @classmethod
    def create(cls, n_cells: int) -> "ZoomState":
        return cls(np.zeros(n_cells, np.int32), np.zeros(n_cells))


def reset_cells(state: ZoomState, cells: np.ndarray) -> ZoomState:
    """Newly added cells start at the lowest zoom."""
    zi = state.zoom_idx.copy()
    zs = state.zoomed_since.copy()
    zi[cells] = 0
    zs[cells] = 0.0
    return ZoomState(zi, zs)


def select_zoom(grid: OrientationGrid, cfg: ZoomConfig, state: ZoomState,
                cell: int, box_centers: np.ndarray, box_sizes: np.ndarray,
                dt: float) -> int:
    """Choose the zoom index for `cell` this timestep.

    box_centers [K, 2] / box_sizes [K, 2] in scene degrees for boxes the
    approximation model saw in this cell (K may be 0).
    """
    zi = int(state.zoom_idx[cell])
    # forced zoom-out timer
    if zi > 0 and state.zoomed_since[cell] + dt >= cfg.zoom_out_after:
        return 0
    if box_centers.shape[0] == 0:
        return 0  # nothing visible: widest view

    centroid = box_centers.mean(0)
    spread = np.linalg.norm(box_centers - centroid, axis=1).mean()
    extent = box_sizes.max() if box_sizes.size else 0.0
    cluster_radius = spread + extent

    # deepest zoom whose (margin-shrunk) half-FOV still contains the cluster
    best = 0
    cell_center = grid.centers[cell]
    off = np.linalg.norm(box_centers.mean(0) - cell_center)
    for i, z in enumerate(cfg.zoom_levels):
        fw, fh = grid.fov(z)
        half = min(fw, fh) / 2.0
        if (cluster_radius + off) <= cfg.margin * half:
            best = i
    return best


def step(grid: OrientationGrid, cfg: ZoomConfig, state: ZoomState,
         shape_cells: np.ndarray, per_cell_boxes: dict, dt: float
         ) -> tuple[ZoomState, np.ndarray]:
    """Advance zoom state for all cells in the shape.

    per_cell_boxes: {cell: (centers [K,2], sizes [K,2])} in scene degrees.
    Returns (new_state, zoom_idx_per_cell [n_cells]).
    """
    zi = state.zoom_idx.copy()
    zs = state.zoomed_since.copy()
    for cell in shape_cells:
        centers, sizes = per_cell_boxes.get(
            int(cell), (np.zeros((0, 2)), np.zeros((0, 2))))
        new_zi = select_zoom(grid, cfg, state, int(cell), centers, sizes, dt)
        if new_zi > 0 and zi[cell] > 0:
            zs[cell] += dt
        elif new_zi > 0:
            zs[cell] = 0.0
        else:
            zs[cell] = 0.0
        zi[cell] = new_zi
    return ZoomState(zi, zs), zi
