"""EWMA orientation labels (paper §3.3).

Each orientation carries two exponentially weighted moving averages over
the last ~10 timesteps: (1) predicted workload accuracy, and (2) the deltas
between consecutive predicted accuracies. The label that drives shape
evolution combines both — "remain robust to inconsistencies in DNN results
across consecutive frames".

Implemented as a pure-JAX pytree so a fleet of cameras vmaps over it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

WINDOW = 10
ALPHA = 2.0 / (WINDOW + 1.0)


class EWMAState(NamedTuple):
    acc: jnp.ndarray        # [N] EWMA of predicted accuracy
    delta: jnp.ndarray      # [N] EWMA of accuracy deltas
    last: jnp.ndarray       # [N] last observed predicted accuracy
    seen: jnp.ndarray       # [N] visit counts (float)


def init_state(n_cells: int) -> EWMAState:
    z = jnp.zeros((n_cells,), jnp.float32)
    return EWMAState(z, z, z, z)


def update(state: EWMAState, visited: jnp.ndarray,
           acc_values: jnp.ndarray, alpha: float = ALPHA) -> EWMAState:
    """visited [N] bool — cells explored this timestep;
    acc_values [N] — predicted accuracy for visited cells (junk elsewhere).
    """
    v = visited.astype(jnp.float32)
    first = (state.seen == 0) & visited
    acc_new = jnp.where(first, acc_values,
                        alpha * acc_values + (1 - alpha) * state.acc)
    acc = jnp.where(visited, acc_new, state.acc)

    d = acc_values - state.last
    delta_new = jnp.where(first, 0.0, alpha * d + (1 - alpha) * state.delta)
    delta = jnp.where(visited, delta_new, state.delta)

    last = jnp.where(visited, acc_values, state.last)
    seen = state.seen + v
    return EWMAState(acc, delta, last, seen)


def labels(state: EWMAState, *, delta_weight: float = 0.5,
           eps: float = 1e-3) -> jnp.ndarray:
    """Per-orientation potential for the next timestep (paper: EWMA of
    values + EWMA of deltas). Strictly positive so head/tail ratios are
    well-defined."""
    raw = state.acc + delta_weight * state.delta
    return jnp.maximum(raw, 0.0) + eps


def decay_unvisited(state: EWMAState, visited: jnp.ndarray,
                    rate: float = 0.98) -> EWMAState:
    """Slight optimism decay for cells not visited this step: their EWMA
    drifts toward the mean so stale highs don't pin the shape forever."""
    acc = jnp.where(visited, state.acc, state.acc * rate)
    return state._replace(acc=acc)
