"""Baseline orientation-selection strategies (paper §2.2 / §5.3).

All baselines consume the same evaluation substrate: an accuracy table
acc[t, cell] (workload accuracy if the camera sits at `cell` during
timestep t, at that cell's best zoom) plus auxiliary per-cell object
statistics. Oracle schemes read the table directly; online schemes
(Panoptes, tracking, UCB1) only see what they visited — mirroring their
real information models.

Each returns `choices` [T] (cell visited per timestep) or [T, k] when the
scheme ships multiple orientations.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grid import OrientationGrid


# ---------------------------------------------------------------------------
# Oracle baselines (paper §2.2)
# ---------------------------------------------------------------------------

def one_time_fixed(acc: np.ndarray) -> np.ndarray:
    """Pick the best cell at t=0 and never move."""
    cell = int(np.argmax(acc[0]))
    return np.full(acc.shape[0], cell)


def best_fixed(acc: np.ndarray, k: int = 1) -> np.ndarray:
    """Oracle best fixed orientation(s) over the whole video.

    k > 1 models deploying k fixed cameras (best, 2nd best, ...)."""
    mean = acc.mean(0)
    cells = np.argsort(-mean)[:k]
    return np.tile(cells, (acc.shape[0], 1)) if k > 1 else \
        np.full(acc.shape[0], int(cells[0]))


def best_dynamic(acc: np.ndarray) -> np.ndarray:
    """Oracle best cell per timestep."""
    return np.argmax(acc, axis=1)


# ---------------------------------------------------------------------------
# Panoptes [90] — weighted round-robin with motion triggers
# ---------------------------------------------------------------------------

@dataclass
class PanoptesConfig:
    dwell_base: int = 3          # timesteps per scheduled stop
    motion_thresh: float = 0.5   # motion gradient to trigger a switch
    trigger_dwell: int = 8       # timesteps to linger after a trigger


def panoptes(acc: np.ndarray, motion: np.ndarray,
             interest: np.ndarray | None = None,
             cfg: PanoptesConfig = PanoptesConfig(),
             grid: OrientationGrid | None = None) -> np.ndarray:
    """motion[t, cell] — motion magnitude; interest[cell] — #queries
    interested (None = all equally). Schedule: static round-robin weighted
    by interest x historical motion; interrupts to a neighboring
    orientation when its motion gradient exceeds the threshold."""
    T, N = acc.shape
    interest = np.ones(N) if interest is None else interest
    hist_motion = motion[: max(T // 10, 1)].mean(0) + 1e-6
    weights = interest * hist_motion
    weights = weights / weights.sum()
    dwells = np.maximum(1, np.round(weights * N * cfg.dwell_base)).astype(int)

    # build the static schedule
    sched = []
    for c in np.argsort(-weights):
        sched.extend([int(c)] * int(dwells[c]))
    choices = np.zeros(T, int)
    i = 0
    t = 0
    trigger_until = -1
    trigger_cell = -1
    while t < T:
        if t < trigger_until:
            choices[t] = trigger_cell
            t += 1
            continue
        cell = sched[i % len(sched)]
        choices[t] = cell
        # motion-gradient trigger toward an overlapping orientation
        if grid is not None and t + 1 < T:
            nbrs = np.flatnonzero(grid.neighbor_mask[cell])
            if nbrs.size:
                grads = motion[t, nbrs] - motion[max(t - 1, 0), nbrs]
                j = int(np.argmax(grads))
                if grads[j] > cfg.motion_thresh:
                    trigger_cell = int(nbrs[j])
                    trigger_until = t + cfg.trigger_dwell
        i += 1
        t += 1
    return choices


# ---------------------------------------------------------------------------
# PTZ tracking [85] — follow the largest object, reset to home
# ---------------------------------------------------------------------------

def tracking(largest_size: np.ndarray, largest_cell: np.ndarray,
             home: int, grid: OrientationGrid) -> np.ndarray:
    """largest_size[t] — size of the globally largest object (0 if none);
    largest_cell[t] — the cell containing it. The tracker can only follow
    to lattice-neighbor cells per step (camera physics) and resets to home
    when the object vanishes."""
    T = largest_size.shape[0]
    choices = np.zeros(T, int)
    cur = home
    tracking_obj = False
    for t in range(T):
        if largest_size[t] <= 0:
            cur = home
            tracking_obj = False
        else:
            target = int(largest_cell[t])
            if not tracking_obj:
                # acquire only if visible from current cell (overlap > 0)
                if grid.overlap_matrix[cur, target] > 0 or cur == target:
                    tracking_obj = True
            if tracking_obj and target != cur:
                # move one lattice hop toward the target
                nbrs = np.flatnonzero(grid.neighbor_mask[cur])
                d = grid.hop_distance[nbrs, target]
                cur = int(nbrs[np.argmin(d)])
            elif not tracking_obj:
                cur = home
        choices[t] = cur
    return choices


# ---------------------------------------------------------------------------
# UCB1 multi-armed bandit [97]
# ---------------------------------------------------------------------------

def ucb1(acc: np.ndarray, seed_steps: int = 0, c: float = 2.0,
         rng: np.random.Generator | None = None) -> np.ndarray:
    """Each orientation is a lever; reward = workload accuracy at visit
    time. Seeded with one pull per arm (historical data per the paper)."""
    T, N = acc.shape
    rng = rng or np.random.default_rng(0)
    counts = np.ones(N)
    # seed with historical means (first few frames)
    means = acc[: max(seed_steps, 1)].mean(0).copy()
    choices = np.zeros(T, int)
    for t in range(T):
        ucb = means + np.sqrt(c * np.log(t + N + 1) / counts)
        cell = int(np.argmax(ucb))
        choices[t] = cell
        r = acc[t, cell]
        counts[cell] += 1
        means[cell] += (r - means[cell]) / counts[cell]
    return choices


def evaluate_choices(acc: np.ndarray, choices: np.ndarray) -> float:
    """Mean workload accuracy of a per-timestep selection.

    choices [T] or [T, k] (multi-camera: best of the k per timestep)."""
    if choices.ndim == 1:
        return float(acc[np.arange(acc.shape[0]), choices].mean())
    picked = np.take_along_axis(acc, choices, axis=1)
    return float(picked.max(1).mean())
