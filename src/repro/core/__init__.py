"""MadEye's primary contribution (paper §3) as composable modules.

  grid.py       orientation grid geometry (pan x tilt x zoom)
  ewma.py       EWMA orientation labels (JAX, fleet-vmappable)
  search.py     contiguous-shape evolution (head/tail swap algorithm)
  neighbor.py   bbox-centroid neighbor-candidate scoring
  path.py       precomputed-MST TSP 2-approx reachability + path selection
  zoom.py       bbox-clustering zoom controller (3 s auto zoom-out)
  rank.py       per-task predicted workload accuracy + ranking
  tradeoff.py   explore-vs-transmit budget balancer
  continual.py  orientation-balanced replay + frozen-backbone fine-tuning
  distill.py    teacher-label generation + rank-quality metrics
  baselines.py  one-time/best-fixed/best-dynamic/Panoptes/tracking/UCB1
  madeye.py     MadEyeController gluing it all per timestep
"""
from repro.core.grid import DEFAULT_GRID, OrientationGrid
from repro.core.madeye import MadEyeController, Observation, StepResult
from repro.core.rank import Query, Workload
