"""Predicted workload accuracy + orientation ranking (paper §3.1).

MadEye post-processes the approximation models' bounding boxes into
per-orientation *predicted workload accuracies*, computed relatively
against the other orientations explored this timestep:

  binary classification : 1 if any object of interest else 0
  counting              : count / max count among explored
  detection             : count + area term (mAP proxy) / max
  aggregate counting    : count score modulated to favor less-explored
                          orientations (unseen objects may hide there)

The workload prediction is the mean over its queries; global ranking
sorts explored orientations by that value.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

TASKS = ("binary", "count", "detect", "agg_count")


@dataclass(frozen=True)
class Query:
    model: str            # teacher model id (e.g. "yolov4", "ssd")
    obj: str              # "person" | "car"
    task: str             # one of TASKS

    def __post_init__(self):
        assert self.task in TASKS, self.task


@dataclass(frozen=True)
class Workload:
    queries: tuple[Query, ...]

    @property
    def objects(self) -> set[str]:
        return {q.obj for q in self.queries}

    @property
    def models(self) -> set[str]:
        return {q.model for q in self.queries}


def query_scores(task: str, counts: np.ndarray, areas: np.ndarray,
                 visits: np.ndarray) -> np.ndarray:
    """Per-orientation predicted accuracy for one query.

    counts [K] — #objects-of-interest the approx model saw per explored
    orientation; areas [K] — summed box areas (mAP proxy); visits [K] —
    historical visit counts (aggregate-counting novelty bonus).
    """
    counts = counts.astype(np.float64)
    if task == "binary":
        return (counts > 0).astype(np.float64)
    if task == "count":
        m = counts.max()
        return counts / m if m > 0 else np.zeros_like(counts)
    if task == "detect":
        # count + area proxy: finding the same count with larger boxes is
        # worth more mAP (better localization odds)
        m = counts.max()
        cscore = counts / m if m > 0 else np.zeros_like(counts)
        am = areas.max()
        ascore = areas / am if am > 0 else np.zeros_like(areas)
        return 0.7 * cscore + 0.3 * ascore
    if task == "agg_count":
        m = counts.max()
        base = counts / m if m > 0 else np.zeros_like(counts)
        novelty = 1.0 / np.sqrt(1.0 + visits)
        s = base * (1.0 + novelty)
        sm = s.max()
        return s / sm if sm > 0 else s
    raise ValueError(task)


def predict_workload_accuracy(workload: Workload,
                              per_query_counts: dict,
                              per_query_areas: dict,
                              visits: np.ndarray) -> np.ndarray:
    """per_query_counts[(model, obj)] -> counts [K] from that query's
    approximation model. Returns predicted workload accuracy [K]."""
    total = None
    for q in workload.queries:
        key = (q.model, q.obj)
        s = query_scores(q.task, per_query_counts[key],
                         per_query_areas[key], visits)
        total = s if total is None else total + s
    return total / len(workload.queries)


def rank_orientations(pred_acc: np.ndarray) -> np.ndarray:
    """Descending rank order (indices into the explored set)."""
    return np.argsort(-pred_acc, kind="stable")


def detections_to_counts(det_boxes: np.ndarray, det_scores: np.ndarray,
                         det_classes: np.ndarray, obj_class: int, *,
                         score_thresh: float = 0.5):
    """Static-shape detections -> (count, area_sum) for one image."""
    keep = (det_scores >= score_thresh) & (det_classes == obj_class)
    count = int(keep.sum())
    areas = det_boxes[:, 2] * det_boxes[:, 3]
    return count, float((areas * keep).sum())
