"""Reachability + path selection (paper §3.3).

Covering a shape of orientations within the timestep is a metric-TSP
(pairwise rotation times satisfy the triangle inequality). MadEye uses the
MST 2-approximation with the heavy lifting precomputed:

  offline: pairwise distance matrix + full-grid MST (Prim);
  online:  induce the forest on the shape's cells, reconnect the few
           components with the cheapest cross edges, preorder-walk from the
           camera's current cell, sum rotation times.

Online cost is linear in shape size; the paper reports 14 µs per path and
92%-of-optimal paths — we assert the same order in tests/benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from repro.core.grid import OrientationGrid


def prim_mst(dist: np.ndarray) -> list[tuple[int, int]]:
    """MST edges over a dense distance matrix (Prim, O(n^2))."""
    n = dist.shape[0]
    in_tree = np.zeros(n, bool)
    best = np.full(n, np.inf)
    parent = np.full(n, -1)
    best[0] = 0.0
    edges = []
    for _ in range(n):
        i = int(np.argmin(np.where(in_tree, np.inf, best)))
        in_tree[i] = True
        if parent[i] >= 0:
            edges.append((int(parent[i]), i))
        improve = dist[i] < best
        mask = improve & ~in_tree
        best[mask] = dist[i][mask]
        parent[mask] = i
    return edges


@dataclass
class PathPlanner:
    grid: OrientationGrid

    def __post_init__(self):
        self.dist = self.grid.angular_distance        # degrees
        self.mst_edges = prim_mst(self.dist)
        self.adj = [[] for _ in range(self.grid.n_cells)]
        for a, b in self.mst_edges:
            self.adj[a].append(b)
            self.adj[b].append(a)

    # ------------------------------------------------------------------
    def subtree_walk(self, cells: np.ndarray, start: int) -> list[int]:
        """Preorder walk visiting `cells` (bool mask), starting at `start`.

        Uses the precomputed full-grid MST restricted to the shape;
        disconnected components are stitched with their cheapest cross
        edge (still a 2-approx by the triangle inequality).
        """
        nodes = np.flatnonzero(cells)
        if nodes.size == 0:
            return []
        node_set = set(int(x) for x in nodes)
        if start not in node_set:
            start = int(nodes[np.argmin(self.dist[start][nodes])])

        # components of the induced forest
        comp = {}
        for n in node_set:
            if n in comp:
                continue
            stack, cid = [n], n
            comp[n] = cid
            while stack:
                u = stack.pop()
                for v in self.adj[u]:
                    if v in node_set and v not in comp:
                        comp[v] = cid
                        stack.append(v)

        # stitch components to the start's component greedily: cheapest
        # cross edge between the done set and the rest, ties broken by
        # row-major (u, v) node order so the JAX fleet walk (repro.fleet)
        # makes the identical choice
        extra_adj: dict[int, list[int]] = {n: [] for n in node_set}
        done_nodes = sorted(n for n in node_set if comp[n] == comp[start])
        rest = sorted(node_set - set(done_nodes))
        while rest:
            sub = self.dist[np.ix_(done_nodes, rest)]
            k = np.unravel_index(np.argmin(sub), sub.shape)
            u, v = done_nodes[k[0]], rest[k[1]]
            extra_adj[u].append(v)
            extra_adj[v].append(u)
            joined = sorted(n for n in rest if comp[n] == comp[v])
            done_nodes = sorted(set(done_nodes) | set(joined))
            rest = [n for n in rest if comp[n] != comp[v]]

        # preorder DFS over (MST ∩ shape) + stitch edges
        order, seen, stack = [], set(), [start]
        while stack:
            u = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            order.append(u)
            nbrs = [v for v in self.adj[u] if v in node_set] + extra_adj[u]
            # visit nearest-first (pop order reversed); ties toward the
            # lower cell id, deterministically (matches the fleet walk)
            nbrs = sorted(set(nbrs) - seen,
                          key=lambda v: (-self.dist[u][v], -v))
            stack.extend(nbrs)
        return order

    # ------------------------------------------------------------------
    def path_time(self, order: list[int], rotation_speed: float,
                  from_cell: int | None = None) -> float:
        """Seconds to traverse `order` (degrees / (deg/s))."""
        if not order:
            return 0.0
        t = 0.0
        prev = from_cell if from_cell is not None else order[0]
        for c in order:
            t += self.dist[prev][c] / rotation_speed
            prev = c
        return t

    def feasible(self, cells: np.ndarray, start: int, *,
                 rotation_speed: float, time_budget: float,
                 per_cell_cost: float = 0.0) -> tuple[bool, list[int], float]:
        """Can the shape be covered in `time_budget` seconds?

        per_cell_cost = capture + approx-model inference per orientation
        (pipelined with rotation in MadEye, so only the max matters; we
        charge the conservative sum of rotation + per-cell costs).
        """
        order = self.subtree_walk(cells, start)
        t = self.path_time(order, rotation_speed, from_cell=start)
        t += per_cell_cost * len(order)
        return t <= time_budget, order, t

    def shrink_to_budget(self, cells: np.ndarray, start: int, labels,
                         *, rotation_speed: float, time_budget: float,
                         per_cell_cost: float = 0.0,
                         grid: OrientationGrid | None = None):
        """Paper: 'upon failure, greedily remove the orientation with the
        lowest potential (that does not break contiguity) and recheck'."""
        from repro.core.grid import removal_keeps_contiguity
        g = grid or self.grid
        cells = cells.copy()
        while True:
            ok, order, t = self.feasible(
                cells, start, rotation_speed=rotation_speed,
                time_budget=time_budget, per_cell_cost=per_cell_cost)
            if ok or cells.sum() <= 1:
                return cells, order, t
            cand = np.flatnonzero(cells)
            cand = sorted(cand, key=lambda c: labels[c])
            removed = False
            for c in cand:
                if cells[c] and removal_keeps_contiguity(cells, c, g):
                    cells[c] = False
                    removed = True
                    break
            if not removed:  # pathological; drop the worst regardless
                cells[cand[0]] = False


@lru_cache(maxsize=8)
def planner_for(grid: OrientationGrid) -> PathPlanner:
    return PathPlanner(grid)
