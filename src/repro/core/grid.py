"""Orientation grid geometry (paper §2.2 / §5.1).

The default grid mirrors the paper: a 150°x75° scene carved into 30° pan x
15° tilt steps -> 5x5 = 25 rotations, each with zoom in {1, 2, 3}. The
search shape (§3.3) lives on the 25 rotation cells; zoom is a per-cell
controller (core/zoom.py).

Field of view at zoom 1 is (2*pan_step, 2*tilt_step) so direct neighbors
overlap by 50% — matching the paper's observation that neighboring
orientations exhibit substantial content overlap (LPIPS 0.30).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np


@dataclass(frozen=True)
class OrientationGrid:
    pan_extent: float = 150.0       # degrees
    tilt_extent: float = 75.0
    pan_step: float = 30.0
    tilt_step: float = 15.0
    n_zoom: int = 3
    fov_scale: float = 2.0          # FOV at zoom 1 = fov_scale * step

    @property
    def n_pan(self) -> int:
        return int(round(self.pan_extent / self.pan_step))

    @property
    def n_tilt(self) -> int:
        return int(round(self.tilt_extent / self.tilt_step))

    @property
    def n_cells(self) -> int:
        return self.n_pan * self.n_tilt

    @property
    def n_orientations(self) -> int:
        return self.n_cells * self.n_zoom

    # ---- index <-> coordinates ------------------------------------------

    def cell_index(self, pi: int, ti: int) -> int:
        return ti * self.n_pan + pi

    def cell_coords(self, idx: int) -> tuple[int, int]:
        return idx % self.n_pan, idx // self.n_pan

    def cell_center(self, idx: int) -> tuple[float, float]:
        """(pan°, tilt°) of the cell center within the scene."""
        pi, ti = self.cell_coords(idx)
        return ((pi + 0.5) * self.pan_step, (ti + 0.5) * self.tilt_step)

    def fov(self, zoom: float) -> tuple[float, float]:
        return (self.fov_scale * self.pan_step / zoom,
                self.fov_scale * self.tilt_step / zoom)

    # ---- precomputed geometry (cached, numpy) ----------------------------

    @cached_property
    def centers(self) -> np.ndarray:
        """[n_cells, 2] (pan, tilt) centers in degrees."""
        return np.array([self.cell_center(i) for i in range(self.n_cells)])

    @cached_property
    def angular_distance(self) -> np.ndarray:
        """[n_cells, n_cells] max-axis rotation distance in degrees.

        PTZ pan and tilt motors run concurrently, so travel time is
        governed by the larger of the two rotations (Chebyshev metric) —
        this also satisfies the triangle inequality required by the
        MST/TSP heuristic (paper §3.3).
        """
        d = np.abs(self.centers[:, None, :] - self.centers[None, :, :])
        return d.max(-1)

    @cached_property
    def hop_distance(self) -> np.ndarray:
        """[n_cells, n_cells] Chebyshev hop count on the pan-tilt lattice."""
        coords = np.array([self.cell_coords(i) for i in range(self.n_cells)])
        d = np.abs(coords[:, None, :] - coords[None, :, :])
        return d.max(-1)

    @cached_property
    def neighbor_mask(self) -> np.ndarray:
        """[n_cells, n_cells] bool — 8-connected lattice neighbors."""
        h = self.hop_distance
        return (h == 1)

    @cached_property
    def adjacency4(self) -> np.ndarray:
        """[n_cells, n_cells] bool — 4-connected (contiguity definition)."""
        coords = np.array([self.cell_coords(i) for i in range(self.n_cells)])
        d = np.abs(coords[:, None, :] - coords[None, :, :])
        return (d.sum(-1) == 1)

    def overlap_fraction(self, i: int, j: int, zoom: float = 1.0) -> float:
        """Fractional FOV overlap between cells i and j at a given zoom."""
        fw, fh = self.fov(zoom)
        ci, cj = self.centers[i], self.centers[j]
        ow = max(0.0, fw - abs(ci[0] - cj[0]))
        oh = max(0.0, fh - abs(ci[1] - cj[1]))
        return (ow * oh) / (fw * fh)

    @cached_property
    def overlap_matrix(self) -> np.ndarray:
        """[n_cells, n_cells] FOV overlap fraction at zoom 1."""
        n = self.n_cells
        out = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                out[i, j] = self.overlap_fraction(i, j)
        return out


DEFAULT_GRID = OrientationGrid()


def contiguous(mask: np.ndarray, grid: OrientationGrid) -> bool:
    """Is the set of cells in `mask` 8-connected? (numpy flood fill).

    8-connectivity matches the Chebyshev hop metric: a diagonal move is a
    single concurrent pan+tilt rotation, so diagonal cells are one hop
    apart both physically and for shape contiguity."""
    idx = np.flatnonzero(mask)
    if idx.size <= 1:
        return True
    adj = grid.neighbor_mask
    seen = np.zeros(grid.n_cells, bool)
    stack = [int(idx[0])]
    seen[idx[0]] = True
    while stack:
        i = stack.pop()
        for j in np.flatnonzero(adj[i] & mask & ~seen):
            seen[j] = True
            stack.append(int(j))
    return bool(seen[mask].all())


def removal_keeps_contiguity(mask: np.ndarray, cell: int,
                             grid: OrientationGrid) -> bool:
    m = mask.copy()
    m[cell] = False
    return contiguous(m, grid)
