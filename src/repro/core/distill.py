"""Knowledge-distillation label generation (paper §3.1-3.2).

Approximation models are trained to mimic *the registered query's model*,
not ground truth — the whole point is to capture that teacher's biases
(what it can discern, at which scales, under which orientations). The
teacher's detections on a frame become the student's training targets.

`teacher_labels` converts any teacher output into the static-shape target
tensors `detector_loss` consumes. `distill_batch` packages a replay-buffer
sample into one training batch.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class DistillTargets(NamedTuple):
    boxes: np.ndarray     # [B, N, 4] cxcywh in [0,1]
    classes: np.ndarray   # [B, N] int32
    valid: np.ndarray     # [B, N] bool


def teacher_labels(teacher_boxes: list, teacher_classes: list,
                   max_boxes: int) -> DistillTargets:
    """Per-image variable-length teacher detections -> static targets.

    teacher_boxes: list (len B) of [k_i, 4] arrays; teacher_classes:
    list of [k_i] arrays. Extra boxes beyond max_boxes are dropped by
    descending area (small boxes are least informative for ranking).
    """
    B = len(teacher_boxes)
    boxes = np.zeros((B, max_boxes, 4), np.float32)
    classes = np.zeros((B, max_boxes), np.int32)
    valid = np.zeros((B, max_boxes), bool)
    for i, (bb, cc) in enumerate(zip(teacher_boxes, teacher_classes)):
        bb = np.asarray(bb, np.float32).reshape(-1, 4)
        cc = np.asarray(cc, np.int32).reshape(-1)
        if bb.shape[0] > max_boxes:
            order = np.argsort(-(bb[:, 2] * bb[:, 3]))[:max_boxes]
            bb, cc = bb[order], cc[order]
        k = bb.shape[0]
        boxes[i, :k] = bb
        classes[i, :k] = cc
        valid[i, :k] = True
    return DistillTargets(boxes, classes, valid)


def rank_agreement(pred_scores: np.ndarray, true_scores: np.ndarray) -> float:
    """Training-accuracy proxy the tradeoff balancer consumes: how often
    does the student rank the best orientation in the top slot?

    Both arrays [K] over the same explored orientations."""
    if pred_scores.size == 0:
        return 1.0
    return float(np.argmax(pred_scores) == np.argmax(true_scores))


def spearman(pred_scores: np.ndarray, true_scores: np.ndarray) -> float:
    """Rank-correlation metric for the Fig-16 style microbenchmark."""
    if pred_scores.size < 2:
        return 1.0
    pr = np.argsort(np.argsort(-pred_scores))
    tr = np.argsort(np.argsort(-true_scores))
    n = pred_scores.size
    return float(1 - 6 * np.sum((pr - tr) ** 2) / (n * (n ** 2 - 1)))
