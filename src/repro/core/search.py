"""Contiguous-shape evolution — the head/tail swap algorithm (paper §3.3).

Per timestep the camera explores a flexible shape of contiguous
orientations. The next shape is derived from the current one by swapping
low-potential members (tail T of the label ordering) for neighbors of
high-potential members (head H), guarded by three conditions:

  1. labels[H] / labels[T] > threshold   (threshold grows with every
     additional neighbor added for the same H — "additional uncertainty");
  2. H has lattice neighbors not already in the shape;
  3. removing T keeps the shape 4-connected.

Neighbor choice among H's candidates uses bbox-centroid geometry
(core/neighbor.py). The shape resets to a rectangular seed whenever the
previous timestep found zero objects of interest anywhere in the shape.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import neighbor as nb
from repro.core.grid import OrientationGrid, removal_keeps_contiguity


def best_rect(grid: OrientationGrid, size: int) -> tuple[int, int]:
    """Most-square (w, h) with w*h <= size on the grid lattice.

    Shared by the numpy seed below and the fleet seed table
    (repro.fleet.state._rect_table) so the two controllers can never
    disagree on the seed geometry."""
    size = int(max(1, min(size, grid.n_cells)))
    best = (1, 1)
    for w in range(1, grid.n_pan + 1):
        for h in range(1, grid.n_tilt + 1):
            if w * h <= size and w * h > best[0] * best[1]:
                best = (w, h)
            elif (w * h == best[0] * best[1]
                  and abs(w - h) < abs(best[0] - best[1])):
                best = (w, h)
    return best


def seed_shape(grid: OrientationGrid, size: int,
               center_cell: int | None = None) -> np.ndarray:
    """Largest coverable rectangle of ~`size` cells around a center.

    Paper: 'MadEye begins with a rectangular seed shape that reflects the
    largest coverable area in the time budget, maximizing early
    exploration.'
    """
    w, h = best_rect(grid, size)
    if center_cell is None:
        center_cell = grid.cell_index(grid.n_pan // 2, grid.n_tilt // 2)
    cp, ct = grid.cell_coords(center_cell)
    p0 = int(np.clip(cp - w // 2, 0, grid.n_pan - w))
    t0 = int(np.clip(ct - h // 2, 0, grid.n_tilt - h))
    mask = np.zeros(grid.n_cells, bool)
    for dp in range(w):
        for dt in range(h):
            mask[grid.cell_index(p0 + dp, t0 + dt)] = True
    return mask


@dataclass
class SearchConfig:
    base_threshold: float = 1.25   # H/T label ratio to justify a swap
    threshold_growth: float = 1.25  # per extra neighbor for the same H
    max_swaps: int = 8             # safety bound per timestep


def evolve_shape(grid: OrientationGrid, shape_mask: np.ndarray,
                 labels: np.ndarray, centroids: np.ndarray,
                 has_boxes: np.ndarray,
                 cfg: SearchConfig = SearchConfig()) -> np.ndarray:
    """One head/tail evolution pass. Returns the next shape mask.

    labels [n_cells] — strictly positive potentials (core/ewma.labels);
    centroids/has_boxes — bbox geometry per cell (core/neighbor).
    """
    mask = shape_mask.copy()
    members = np.flatnonzero(mask)
    if members.size == 0:
        return mask
    if members.size == 1:
        # Degenerate budget (tight fps x slow rotation): the "shape" is a
        # single cell. Drift it toward the neighbor its own boxes are
        # heading for when that neighbor's potential justifies the move;
        # if any cell's EWMA label beats the current cell by a wide margin
        # (e.g. the hotspot moved while we were pinned), jump straight to
        # it — the path planner charges the rotation.
        H = int(members[0])
        best_global = int(np.argmax(labels))
        if (best_global != H
                and labels[best_global] > labels[H] * 2 * cfg.base_threshold):
            mask[H] = False
            mask[best_global] = True
            return mask
        cands, scores = nb.score_candidates(grid, mask, H, centroids,
                                            has_boxes)
        if cands.size == 0:
            return mask
        best = int(cands[np.argmax(scores)])
        moving_away = scores.max() > 1.05      # boxes drifting off-center
        promising = labels[best] > labels[H] * cfg.base_threshold
        if moving_away or promising:
            mask[H] = False
            mask[best] = True
        return mask
    # stable sort: ties break toward the lower cell id on both the numpy
    # and the fleet (JAX) implementation, keeping them in lockstep
    order = members[np.argsort(-labels[members], kind="stable")]
    h_i, t_i = 0, len(order) - 1
    thresh = cfg.base_threshold
    failed_once = False
    swaps = 0

    while h_i < t_i and swaps < cfg.max_swaps:
        H, T = int(order[h_i]), int(order[t_i])
        if labels[H] / max(labels[T], 1e-9) <= thresh:
            break  # no sufficient disparity left

        cand = nb.best_candidate(grid, mask, H, centroids, has_boxes)
        if cand is None:
            if failed_once:
                break  # paper: end when even one neighbor can't be added
            failed_once = True
            h_i += 1
            thresh = cfg.base_threshold
            continue

        trial = mask.copy()
        trial[cand] = True
        if not removal_keeps_contiguity(trial, T, grid):
            # this tail is structurally load-bearing; try the next one
            t_i -= 1
            continue

        trial[T] = False
        mask = trial
        failed_once = False
        swaps += 1
        t_i -= 1
        thresh *= cfg.threshold_growth  # next neighbor for same H is riskier
    return mask


def resize_shape(grid: OrientationGrid, mask: np.ndarray, labels: np.ndarray,
                 centroids: np.ndarray, has_boxes: np.ndarray,
                 target_size: int) -> np.ndarray:
    """Grow/shrink the shape to the budgeted size while keeping contiguity.

    Growth adds the best-scored neighbor of the highest-label member with
    free neighbors; shrinkage removes the lowest-label member whose removal
    keeps the shape 4-connected.
    """
    mask = mask.copy()
    target_size = int(np.clip(target_size, 1, grid.n_cells))
    # grow
    while mask.sum() < target_size:
        members = np.flatnonzero(mask)
        order = members[np.argsort(-labels[members], kind="stable")]
        added = False
        for H in order:
            cand = nb.best_candidate(grid, mask, int(H), centroids, has_boxes)
            if cand is not None:
                mask[cand] = True
                added = True
                break
        if not added:
            break
    # shrink
    while mask.sum() > target_size:
        members = np.flatnonzero(mask)
        order = members[np.argsort(labels[members], kind="stable")]
        removed = False
        for T in order:
            if removal_keeps_contiguity(mask, int(T), grid):
                mask[T] = False
                removed = True
                break
        if not removed:
            mask[order[0]] = False
    return mask


def shape_stats(mask: np.ndarray, grid: OrientationGrid) -> dict:
    cells = np.flatnonzero(mask)
    if cells.size == 0:
        return {"size": 0, "max_span_deg": 0.0}
    centers = grid.centers[cells]
    span = (centers.max(0) - centers.min(0)).max()
    return {"size": int(cells.size), "max_span_deg": float(span)}
