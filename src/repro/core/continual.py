"""Continual learning with orientation-balanced replay (paper §3.2).

Within each retraining window only the orientations MadEye actually
visited (and deemed send-worthy) produce fresh samples — a severely
imbalanced set (the paper measures 9.3% orientation coverage per 2-minute
window). Training on it as-is overfits recent orientations and
catastrophically forgets ones about to become relevant.

The fix mirrors the paper exactly:
  * neighbors within 3 hops of the latest orientation are PADDED (via the
    historical buffer) up to the sample count of the most popular
    orientation in the window;
  * farther orientations contribute exponentially fewer samples with hop
    distance.

`finetune_step` is the jit'd gradient step: frozen backbone (stop-gradient
+ optimizer mask), heads-only AdamW.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import numpy as np

from repro.configs.base import DetectorConfig
from repro.core.grid import OrientationGrid


# ---------------------------------------------------------------------------
# Replay buffer with per-orientation bookkeeping
# ---------------------------------------------------------------------------

@dataclass
class ReplayBuffer:
    """Most-recent samples per orientation cell.

    LEGACY host-side reference: the in-scan counterpart is the
    device-resident per-camera ring `repro.learn.pairs.PairBuffer`
    (fixed-shape, rides the episode scan carry). This dict-based buffer
    remains the reference implementation of the paper's
    orientation-balanced replay (`balanced_counts`/`sample_balanced`
    below), which the in-scan ring deliberately does not attempt —
    balancing needs host-side bookkeeping across retraining windows."""
    n_cells: int
    capacity_per_cell: int = 32
    store: dict = field(default_factory=dict)   # cell -> list of samples

    def add(self, cell: int, sample):
        lst = self.store.setdefault(int(cell), [])
        lst.append(sample)
        if len(lst) > self.capacity_per_cell:
            lst.pop(0)

    def count(self, cell: int) -> int:
        return len(self.store.get(int(cell), []))

    def recent(self, cell: int, k: int) -> list:
        return self.store.get(int(cell), [])[-k:]


def balanced_counts(window_counts: np.ndarray, latest_cell: int,
                    grid: OrientationGrid, *, pad_hops: int = 3,
                    decay: float = 0.5) -> np.ndarray:
    """Target per-orientation sample counts for one retraining round.

    window_counts [n_cells] — fresh samples per cell this window.
    Cells <= pad_hops from latest_cell are padded to the max count;
    farther cells get max_count * decay^(hops - pad_hops).
    """
    max_count = int(window_counts.max()) if window_counts.size else 0
    if max_count == 0:
        return np.zeros_like(window_counts)
    hops = grid.hop_distance[latest_cell]
    target = np.where(
        hops <= pad_hops,
        max_count,
        np.maximum(1, np.round(
            max_count * decay ** (hops - pad_hops))).astype(np.int64))
    return target


def sample_balanced(buffer: ReplayBuffer, window_counts: np.ndarray,
                    latest_cell: int, grid: OrientationGrid, *,
                    pad_hops: int = 3, decay: float = 0.5,
                    max_total: int = 256) -> list:
    """Draw a balanced batch of samples from the replay buffer."""
    targets = balanced_counts(window_counts, latest_cell, grid,
                              pad_hops=pad_hops, decay=decay)
    batch = []
    for cell in range(grid.n_cells):
        want = int(targets[cell])
        if want <= 0:
            continue
        batch.extend(buffer.recent(cell, want))
    if len(batch) > max_total:
        idx = np.random.RandomState(0).choice(
            len(batch), max_total, replace=False)
        batch = [batch[i] for i in idx]
    return batch


# ---------------------------------------------------------------------------
# Fine-tune step (frozen backbone, heads-only AdamW) — delegates to
# repro.learn.loop so the offline and in-scan paths share ONE update
# rule (learn.loop.optimizer_apply); this module keeps only the jit
# wrapper for back-compat.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "lr"))
def finetune_step(params, opt_state, cfg: DetectorConfig, images, gt_boxes,
                  gt_classes, gt_valid, *, lr: float = 1e-3):
    """One continual-learning gradient step. Returns (params', state',
    loss)."""
    from repro.learn.loop import finetune_update

    return finetune_update(params, opt_state, cfg, images, gt_boxes,
                           gt_classes, gt_valid, lr=lr)


def init_finetune(params):
    """Optimizer state sized to the heads only (97% state savings)."""
    from repro.learn.loop import init_finetune_state

    return init_finetune_state(params)
