"""Exploration-vs-transmission balancer (paper §3.3).

Each timestep splits into (a) rotating through + approx-scoring explored
orientations and (b) sending the top-k to the backend + running the
workload there; (b) does not overlap (a) because transmission is governed
by global ranks over everything explored.

MadEye sizes k from how much it trusts its approximation models — low
training accuracy or high variance in last-step predictions means ranks
are risky, so send more frames for ground truth — then spends whatever
budget remains on exploration.

Network estimate = harmonic mean of the last 5 transfer rates (robust to
outliers, per adaptive-streaming practice [106]).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class NetworkEstimator:
    window: int = 5
    samples_mbps: list = field(default_factory=list)
    rtt_s: float = 0.02

    def observe(self, mbps: float, rtt_s: float | None = None):
        self.samples_mbps.append(max(mbps, 1e-3))
        if len(self.samples_mbps) > self.window:
            self.samples_mbps.pop(0)
        if rtt_s is not None:
            self.rtt_s = rtt_s

    @property
    def harmonic_mbps(self) -> float:
        if not self.samples_mbps:
            return 24.0
        s = np.asarray(self.samples_mbps)
        return float(len(s) / np.sum(1.0 / s))

    def transfer_time(self, n_bytes: int) -> float:
        return self.rtt_s + (n_bytes * 8) / (self.harmonic_mbps * 1e6)


@dataclass
class BudgetConfig:
    fps: float = 15.0
    rotation_speed: float = 400.0     # degrees/sec
    hop_degrees: float = 30.0         # grid step (matches OrientationGrid)
    approx_infer_s: float = 0.0067    # EfficientDet-D0-class on edge GPU
    backend_infer_s: float = 0.010    # workload inference per frame (TensorRT)
    frame_bytes: int = 25_000         # delta-encoded orientation frame
    min_send: int = 1
    max_send: int = 4
    # Beyond-paper optimization (EXPERIMENTS.md §Perf): pipeline stages
    # across timesteps — the radio transmits step t's frames while the
    # motor explores step t+1. Each stage must fit a timestep, but they
    # no longer compete for the same budget. Default False = paper-strict
    # serial accounting ("transmission ... does not overlap exploration").
    pipelined: bool = False

    @property
    def timestep(self) -> float:
        return 1.0 / self.fps


def frames_to_send(train_acc: float, pred_variance: float,
                   cfg: BudgetConfig) -> int:
    """Risk-adjusted k. Paper example: 85% training accuracy and 25%
    variance -> at least 2 frames."""
    risk = (1.0 - train_acc) + pred_variance
    # 1e-4 guard: the floor cut must not flip with float precision (the
    # initial 0.15 + 0.25 risk lands exactly on a 0.20 boundary, and the
    # f32 fleet controller must take the same branch as this f64 path)
    k = 1 + int(np.floor(risk / 0.20 + 1e-4))
    return int(np.clip(k, cfg.min_send, cfg.max_send))


def exploration_budget(k_send: int, net: NetworkEstimator,
                       cfg: BudgetConfig) -> tuple[float, int]:
    """Time left for exploring after sending k frames + backend inference,
    and the max shape size that fits it.

    Exploration is pipelined with approx inference (paper §3.3), so each
    extra orientation costs max(rotation_hop, approx_infer); we charge the
    conservative sum of one hop + one inference.
    """
    send_time = net.transfer_time(cfg.frame_bytes * k_send)
    backend = cfg.backend_infer_s * k_send
    if cfg.pipelined:
        # stages overlap across timesteps; exploration owns the timestep
        # as long as send/backend each fit one timestep on their own
        t_explore = cfg.timestep if (send_time <= cfg.timestep
                                     and backend <= cfg.timestep) else \
            cfg.timestep - max(0.0, send_time - cfg.timestep) \
            - max(0.0, backend - cfg.timestep)
    else:
        t_explore = cfg.timestep - send_time - backend
    hop_time = cfg.hop_degrees / cfg.rotation_speed
    # rotation overlaps approx inference on the previous capture (§3.3
    # "pipelines its exploration ... with the running of approximation
    # models"), so an extra cell costs the max of the two stages
    per_extra = max(hop_time, cfg.approx_infer_s)
    # first cell is the camera's current orientation: inference only
    extra = (t_explore - cfg.approx_infer_s) / per_extra
    max_cells = 1 + int(max(0, np.floor(extra + 1e-4))) if t_explore > 0 \
        else 1
    return max(t_explore, 0.0), max_cells


def plan_timestep(train_acc: float, pred_variance: float,
                  net: NetworkEstimator, cfg: BudgetConfig):
    """-> (k_send, t_explore_s, max_shape_cells).

    The risk-derived k is lowered until the residual budget can still
    explore at least k orientations — sending more ground-truth frames is
    pointless if it starves the exploration that finds them (the paper's
    explore-vs-transmit tension, resolved coherently)."""
    k = frames_to_send(train_acc, pred_variance, cfg)
    while k > cfg.min_send:
        t_explore, max_cells = exploration_budget(k, net, cfg)
        if max_cells >= k:
            return k, t_explore, max_cells
        k -= 1
    t_explore, max_cells = exploration_budget(k, net, cfg)
    return k, t_explore, max(max_cells, k)
