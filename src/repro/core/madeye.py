"""MadEyeController — ties search + rank + zoom + tradeoff per timestep
(paper Fig. 8 end-to-end workflow, camera side).

The controller is deliberately I/O-free: the serving pipeline hands it an
`observe` callback that captures + approx-scores a set of (cell, zoom)
orientations, and the controller returns which explored frames to ship to
the backend. Host-side state is numpy (this is the camera-CPU logic the
paper measures at 17 µs/step); the fleet-scale JAX reimplementation lives
in repro/fleet (one jit'd scan for a whole camera fleet) and reuses
core/ewma.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple

import numpy as np

from repro.core import rank as rank_mod
from repro.core import search, tradeoff, zoom as zoom_mod
from repro.core.grid import OrientationGrid
from repro.core.path import PathPlanner, planner_for
from repro.core.rank import Workload

EWMA_ALPHA = 2.0 / 11.0      # window 10 (paper §3.3)


class Observation(NamedTuple):
    """What the approximation models saw in one explored orientation."""
    counts: dict          # (model, obj) -> int
    areas: dict           # (model, obj) -> float (sum of box areas)
    centroid: np.ndarray  # [2] mean box center, scene degrees
    has_boxes: bool
    box_centers: np.ndarray  # [K, 2] scene degrees
    box_sizes: np.ndarray    # [K, 2] scene degrees


class StepResult(NamedTuple):
    explored: list            # cell ids in visit order
    zooms: np.ndarray         # zoom index per explored cell
    sent: list                # cell ids shipped to backend (rank order)
    pred_acc: np.ndarray      # predicted workload accuracy per explored cell
    path_time: float


@dataclass
class MadEyeController:
    grid: OrientationGrid
    workload: Workload
    budget: tradeoff.BudgetConfig = field(
        default_factory=tradeoff.BudgetConfig)
    search_cfg: search.SearchConfig = field(
        default_factory=search.SearchConfig)
    zoom_cfg: zoom_mod.ZoomConfig = field(default_factory=zoom_mod.ZoomConfig)
    delta_weight: float = 0.5

    def __post_init__(self):
        n = self.grid.n_cells
        self.planner: PathPlanner = planner_for(self.grid)
        self.net = tradeoff.NetworkEstimator()
        self.zoom_state = zoom_mod.ZoomState.create(n)
        self.shape = search.seed_shape(self.grid, 6)
        self.current_cell = int(np.flatnonzero(self.shape)[0])
        # EWMA label state (numpy mirror of core/ewma.py)
        self.acc_ewma = np.zeros(n)
        self.delta_ewma = np.zeros(n)
        self.last_acc = np.zeros(n)
        self.visits = np.zeros(n)
        self.centroids = np.zeros((n, 2))
        self.has_boxes = np.zeros(n, bool)
        self.cell_boxes: dict = {}  # cell -> (centers [K,2], sizes [K,2])
        self.train_acc = 0.85       # backend-reported approx-model accuracy
        self.pred_var = 0.25
        self.saw_objects = True
        self.step_idx = 0
        self.last_visit = np.full(n, -1000, dtype=np.int64)
        self.scout_every = 8  # 1-cell regime: periodic scout visit

    # ------------------------------------------------------------------
    def labels(self) -> np.ndarray:
        raw = self.acc_ewma + self.delta_weight * self.delta_ewma
        return np.maximum(raw, 0.0) + 1e-3

    def _update_ewma(self, cells: np.ndarray, values: np.ndarray):
        for c, v in zip(cells, values):
            first = self.visits[c] == 0
            if first:
                self.acc_ewma[c] = v
                self.delta_ewma[c] = 0.0
            else:
                self.acc_ewma[c] = (EWMA_ALPHA * v
                                    + (1 - EWMA_ALPHA) * self.acc_ewma[c])
                d = v - self.last_acc[c]
                self.delta_ewma[c] = (EWMA_ALPHA * d
                                      + (1 - EWMA_ALPHA) * self.delta_ewma[c])
            self.last_acc[c] = v
            self.visits[c] += 1

    # ------------------------------------------------------------------
    def step(self, observe: Callable[[list, np.ndarray], list]) -> StepResult:
        """One timestep. `observe(cells, zoom_idx)` must return a list of
        `Observation` (one per cell, same order).

        The fleet-scale JAX reimplementation of this method is
        repro.fleet.step.fleet_step; tests/test_fleet_parity.py keeps the
        two decision-identical."""
        g = self.grid

        # 1. budget: frames to send + target shape size
        k_send, t_explore, max_cells = tradeoff.plan_timestep(
            self.train_acc, self.pred_var, self.net, self.budget)

        # 2. shape: reset on empty scene, else evolve + resize to budget
        if not self.saw_objects:
            # Re-seed around the most promising stale cell: EWMA labels
            # break ties toward least-recently-visited, so empty scenes
            # degrade into a systematic sweep instead of a dead-zone lock.
            staleness = (self.step_idx - self.last_visit).astype(float)
            center = int(np.argmax(self.labels() + 1e-4 * staleness))
            self.shape = search.seed_shape(g, max_cells, center)
            newly = np.flatnonzero(self.shape)
            self.zoom_state = zoom_mod.reset_cells(self.zoom_state, newly)
        else:
            prev = self.shape.copy()
            self.shape = search.evolve_shape(
                g, self.shape, self.labels(), self.centroids,
                self.has_boxes, self.search_cfg)
            self.shape = search.resize_shape(
                g, self.shape, self.labels(), self.centroids,
                self.has_boxes, max_cells)
            # 1-cell regime: the camera would otherwise never learn about
            # the rest of the grid — spend every Nth timestep scouting the
            # most promising stale cell (EWMA label + staleness bonus)
            if (max_cells == 1 and self.scout_every
                    and self.step_idx % self.scout_every
                    == self.scout_every - 1):
                staleness = (self.step_idx - self.last_visit).astype(float)
                score = self.labels() + 1e-3 * np.sqrt(
                    np.maximum(staleness, 0.0))
                score[np.flatnonzero(self.shape)] = -np.inf
                scout = int(np.argmax(score))
                self.shape = np.zeros(g.n_cells, bool)
                self.shape[scout] = True
            newly = np.flatnonzero(self.shape & ~prev)
            if newly.size:
                self.zoom_state = zoom_mod.reset_cells(self.zoom_state, newly)

        # 3. reachability: shrink until coverable in the exploration budget
        #    (timestep minus transmission + backend inference — §3.3).
        #    Rotation overlaps approx inference, so the per-cell charge is
        #    the slack of inference over one hop (usually zero).
        hop_s = g.pan_step / self.budget.rotation_speed
        per_cell = max(0.0, self.budget.approx_infer_s - hop_s)
        budget_s = max(t_explore - self.budget.approx_infer_s,
                       self.budget.approx_infer_s + hop_s)
        self.shape, order, path_time = self.planner.shrink_to_budget(
            self.shape, self.current_cell, self.labels(),
            rotation_speed=self.budget.rotation_speed,
            time_budget=budget_s, per_cell_cost=per_cell)

        # 4. zoom per explored cell (driven by last timestep's boxes)
        empty = (np.zeros((0, 2)), np.zeros((0, 2)))
        per_cell_boxes = {c: self.cell_boxes.get(c, empty) for c in order}
        self.zoom_state, zoom_idx = zoom_mod.step(
            g, self.zoom_cfg, self.zoom_state, np.asarray(order),
            per_cell_boxes, self.budget.timestep)
        zooms = zoom_idx[np.asarray(order, int)] if order else np.zeros(0, int)

        # 5. observe (capture + approx inference along the path)
        obs = observe(order, zooms)

        # 6. rank explored orientations by predicted workload accuracy
        K = len(order)
        per_q_counts = {}
        per_q_areas = {}
        for q in self.workload.queries:
            key = (q.model, q.obj)
            per_q_counts.setdefault(
                key, np.array([o.counts.get(key, 0) for o in obs], float))
            per_q_areas.setdefault(
                key, np.array([o.areas.get(key, 0.0) for o in obs], float))
        visits = self.visits[np.asarray(order, int)] if order else np.zeros(0)
        pred_acc = rank_mod.predict_workload_accuracy(
            self.workload, per_q_counts, per_q_areas, visits)
        ranking = rank_mod.rank_orientations(pred_acc)
        sent = [order[i] for i in ranking[:k_send]]

        # 7. state updates
        self.step_idx += 1
        cells_arr = np.asarray(order, int)
        self.last_visit[cells_arr] = self.step_idx
        self._update_ewma(cells_arr, pred_acc)
        # stale-cell optimism decay (unvisited highs drift down)
        unvisited = np.ones(g.n_cells, bool)
        unvisited[cells_arr] = False
        self.acc_ewma[unvisited] *= 0.995
        for c, o in zip(order, obs):
            self.has_boxes[c] = o.has_boxes
            if o.has_boxes:
                self.centroids[c] = o.centroid
            self.cell_boxes[c] = (o.box_centers, o.box_sizes)
        self.saw_objects = any(o.has_boxes for o in obs)
        self.pred_var = float(np.var(pred_acc)) if K > 1 else 0.0
        self.current_cell = order[-1] if order else self.current_cell

        return StepResult(order, zooms, sent, pred_acc, path_time)

    # ------------------------------------------------------------------
    def report_network(self, mbps: float, rtt_s: float | None = None):
        self.net.observe(mbps, rtt_s)

    def report_train_acc(self, acc: float):
        self.train_acc = float(np.clip(acc, 0.0, 1.0))
