"""Neighbor-candidate scoring from bounding-box geometry (paper §3.3).

When the search adds a neighbor for a head orientation H, candidates are
scored by where the objects inside the current shape sit: for candidate c
and shape member o,

    ratio_o(c) = dist(c_center, o_center) / dist(c_center, bbox_centroid_o)

ratios > 1 mean o's boxes sit on the side facing c (likelier to move into
c next timestep). The candidate score is the overlap-weighted sum of
ratios over all shape members with non-zero FOV overlap with c.

All geometry is in scene degrees; the pipeline converts detector outputs
(per-image [0,1] boxes) to scene coordinates before calling in here.
"""
from __future__ import annotations

import numpy as np

from repro.core.grid import OrientationGrid


def candidate_cells(grid: OrientationGrid, shape_mask: np.ndarray,
                    h_cell: int) -> np.ndarray:
    """Lattice neighbors of h_cell not already in the shape."""
    nbrs = np.flatnonzero(grid.neighbor_mask[h_cell] & ~shape_mask)
    return nbrs


def score_candidates(grid: OrientationGrid, shape_mask: np.ndarray,
                     h_cell: int, centroids: np.ndarray,
                     has_boxes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Score each candidate neighbor of h_cell.

    centroids [n_cells, 2] — mean bbox center per cell in scene degrees
    (junk where has_boxes is False); has_boxes [n_cells] bool.

    Returns (candidates [K], scores [K]); empty arrays if no candidates.
    """
    cands = candidate_cells(grid, shape_mask, h_cell)
    if cands.size == 0:
        return cands, np.zeros(0)

    scores = np.zeros(cands.size)
    for ci, c in enumerate(cands):
        c_center = grid.centers[c]
        total_w, total = 0.0, 0.0
        for o in np.flatnonzero(shape_mask):
            w = grid.overlap_matrix[c, o]
            if w <= 0.0 or not has_boxes[o]:
                continue
            d_center = np.linalg.norm(c_center - grid.centers[o])
            d_boxes = np.linalg.norm(c_center - centroids[o])
            ratio = d_center / max(d_boxes, 1e-6)
            total += w * ratio
            total_w += w
        # no informative overlap: neutral score so geometry alone decides
        scores[ci] = total / total_w if total_w > 0 else 1.0
    return cands, scores


def best_candidate(grid: OrientationGrid, shape_mask: np.ndarray,
                   h_cell: int, centroids: np.ndarray,
                   has_boxes: np.ndarray) -> int | None:
    cands, scores = score_candidates(grid, shape_mask, h_cell, centroids,
                                     has_boxes)
    if cands.size == 0:
        return None
    return int(cands[np.argmax(scores)])
