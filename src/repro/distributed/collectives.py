"""shard_map collective helpers with overlap-friendly schedules.

GSPMD handles most collectives implicitly; these helpers exist for the
places we take manual control:

  * `ring_allgather_kv` — decode-time KV gather as a collective-permute
    ring so each step's chunk transfer overlaps the partial-attention
    compute on the chunk already in hand (flash-decode style);
  * `psum_scatter_grads` — reduce-scatter gradients along the FSDP axis
    (each device keeps only its shard — ZeRO-2/3 wire pattern);
  * `crosspod_allreduce_compressed` lives in train/compression.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def psum_scatter_grads(grads, axis_name: str):
    """Reduce-scatter every gradient leaf along its first shardable dim."""
    def leaf(g):
        n = jax.lax.psum(1, axis_name)
        if g.ndim and g.shape[0] % n == 0:
            return jax.lax.psum_scatter(
                g, axis_name, scatter_dimension=0, tiled=True)
        return jax.lax.psum(g, axis_name)
    return jax.tree.map(leaf, grads)


def ring_allgather(x: jnp.ndarray, axis_name: str):
    """All-gather via N-1 collective-permutes (ring). Returns [N, ...].

    Written so XLA can overlap each permute with caller-side compute on
    the chunk that just arrived (pass a per-chunk callback to
    `ring_reduce_attend`)."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, state):
        buf, cur = state
        buf = jax.lax.dynamic_update_index_in_dim(
            buf, cur, (idx - i) % n, 0)
        cur = jax.lax.ppermute(cur, axis_name, perm)
        return buf, cur

    buf0 = jnp.zeros((n,) + x.shape, x.dtype)
    buf, _ = jax.lax.fori_loop(0, n, body, (buf0, x))
    return buf


def ring_reduce_attend(q, k_shard, v_shard, axis_name: str, *,
                       scale: float):
    """Flash-decode over a sequence-sharded KV cache.

    q [B,1,H,D]; k_shard/v_shard [B,S/n,H,D] (this device's chunk).
    Each device computes partial (max, denom, weighted-V) over its chunk;
    a single psum-based logsumexp combine produces the exact softmax —
    2 small collectives instead of all-gathering S*D cache bytes.
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_shard.astype(jnp.float32)) * scale
    m_local = jnp.max(s, axis=-1, keepdims=True)              # [B,H,1,1]
    m_global = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(s - m_global)
    denom = jax.lax.psum(jnp.sum(p, axis=-1, keepdims=True), axis_name)
    o_part = jnp.einsum("bhqk,bkhd->bqhd", p,
                        v_shard.astype(jnp.float32))
    o = jax.lax.psum(o_part, axis_name) / jnp.maximum(
        denom.transpose(0, 2, 1, 3), 1e-20)
    return o.astype(q.dtype)
