"""Per-architecture PartitionSpec rules (FSDP + TP + EP + SP).

Policy (MaxText-style, adapted per family):

  * `data`-like axes (`pod`,`data`) carry batch (DP) and shard every large
    weight's reduction dim (FSDP/ZeRO-3 — optimizer states follow params);
  * `model` carries tensor parallelism (attention heads / FFN hidden dim),
    expert parallelism (MoE expert axis), and sequence parallelism for the
    long-context decode cells (KV-cache sequence axis);
  * norms / biases / small vectors replicate.

Rules are path+shape based so one function covers dense LM, MoE LM (MLA &
GQA), ViT/Swin, DiT/MMDiT, and the detector. A dim is only sharded when
divisible by the mesh axis size — otherwise it falls back to replication
(GSPMD handles the rest).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """The data-parallel mesh axes (pod+data when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or (names[0],)


def axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

_RULES = [
    # (path regex, spec for the trailing dims)
    (r"embed.*table$", ("model", "data")),
    (r"lm_head.*w$", ("data", "model")),
    (r"(wq|wk|wv)/w$", ("data", "model")),
    (r"wq_b/w$", (None, "model")),
    (r"wkv_b/w$", (None, "model")),
    (r"(wq_a|wkv_a)/w$", ("data", None)),
    (r"wo/w$", ("model", "data")),
    (r"router/w$", ("data", None)),
    (r"w_gate$", ("model", "data", None)),       # [E, D, F] — EP + FSDP
    (r"w_up$", ("model", "data", None)),
    (r"w_down$", ("model", None, "data")),
    (r"shared/(gate|up)/w$", ("data", "model")),
    (r"shared/down/w$", ("model", "data")),
    (r"(up|gate)/w$", ("data", "model")),        # dense MLPs
    (r"down/w$", ("model", "data")),
    (r"(fc1|fc2)/w$", ("data", "model")),
    (r"ada/w$", ("data", "model")),
    (r"final_ada/w$", ("data", "model")),
    (r"(img_in|txt_in|final_proj|head|reduce)/w$", ("data", "model")),
    (r"patch_embed/w$", (None, None, None, "model")),
    (r"(cls|box|obj)/w$", (None, None, "data", None)),  # detector heads
    (r"pos_embed$", (None, None, "data")),
    (r"y_embed$", (None, "data")),
]


def _path_str(kp) -> str:
    """Key-path -> 'layers/attn/wq/w' (keystr() emits bracket syntax that
    the rule regexes must not depend on)."""
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


# Serving-mode rules (§Perf): inference has no optimizer states, so FSDP
# weight sharding only buys per-layer weight all-gathers. TP-only Megatron
# layout — column-parallel in, row-parallel out, one activation all-reduce
# per block — and EP-only expert placement. Enabled per-cell via
# REPRO_SERVE_TP_ONLY=1 (launch/steps.py sets it for serve/decode cells
# when the optimized profile is selected).
_SERVE_RULES = [
    (r"embed.*table$", ("model", None)),
    (r"lm_head.*w$", (None, "model")),
    (r"(wq|wk|wv)/w$", (None, "model")),
    (r"wq_b/w$", (None, "model")),
    (r"wkv_b/w$", (None, "model")),
    (r"(wq_a|wkv_a)/w$", (None, None)),
    (r"wo/w$", ("model", None)),
    (r"router/w$", (None, None)),
    (r"w_gate$", ("model", "data", None)),   # E over TP, D over data:
    (r"w_up$", ("model", "data", None)),      # 1T of experts must spread
    (r"w_down$", ("model", None, "data")),    # across BOTH axes to fit HBM
    (r"shared/(gate|up)/w$", (None, "model")),
    (r"shared/down/w$", ("model", None)),
    (r"(up|gate)/w$", (None, "model")),
    (r"down/w$", ("model", None)),
    (r"(fc1|fc2)/w$", (None, "model")),
    (r"(img_in|txt_in|head)/w$", (None, "model")),
]


def _active_rules():
    import os
    if os.environ.get("REPRO_SERVE_REPLICATED", "") == "1":
        # §Perf: small-model serving — replicate weights entirely; each DP
        # slice runs whole images with zero collectives. TP on an 86M-param
        # model costs more in activation all-reduces than it saves.
        return []
    if os.environ.get("REPRO_SERVE_TP_ONLY", "") == "1":
        return _SERVE_RULES + _RULES
    return _RULES


def _leaf_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    for pat, trailing in _active_rules():
        if re.search(pat, path):
            spec = [None] * len(shape)
            # right-align the rule onto the trailing dims (stacked layers
            # carry a leading L dim that stays unsharded: scan iterates it)
            k = len(trailing)
            if len(shape) < k:
                break
            ok = True
            resolved = []
            for ax_name, dim in zip(trailing, shape[-k:]):
                if ax_name is None:
                    resolved.append(None)
                    continue
                axis = dp_axes(mesh) if ax_name == "data" else ax_name
                if ax_name == "model" and "model" not in mesh.axis_names:
                    resolved.append(None)
                    continue
                resolved.append(axis if _fits(dim, mesh, axis) else None)
            spec[-k:] = resolved
            return P(*spec)
    return P()  # replicate (norms, biases, small tensors)


def param_shardings(params_shape_tree, mesh: Mesh):
    """Pytree of NamedShardings matching a params (or ShapeDtypeStruct)
    tree. Works on the result of jax.eval_shape(init_fn, key)."""
    def leaf(kp, leaf):
        return NamedSharding(mesh, _leaf_spec(_path_str(kp), leaf.shape,
                                              mesh))
    return jax.tree_util.tree_map_with_path(leaf, params_shape_tree)


def opt_shardings(opt_shape_tree, mesh: Mesh):
    """Optimizer states inherit their parameter's sharding (ZeRO-3 —
    scalar leaves (step, masked placeholders) replicate."""
    def leaf(kp, l):
        if len(l.shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _leaf_spec(_path_str(kp), l.shape, mesh))
    return jax.tree_util.tree_map_with_path(leaf, opt_shape_tree)


# ---------------------------------------------------------------------------
# Batch / activation rules
# ---------------------------------------------------------------------------

def batch_shardings(batch_shape_tree, mesh: Mesh, *,
                    microbatched: bool = False):
    """Inputs: leading batch dim over the DP axes (after an optional
    microbatch dim that stays unsharded for lax.scan)."""
    dp = dp_axes(mesh)

    def leaf(l):
        spec = [None] * len(l.shape)
        b_idx = 1 if microbatched else 0
        if len(l.shape) > b_idx and _fits(l.shape[b_idx], mesh, dp):
            spec[b_idx] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(leaf, batch_shape_tree)


def kvcache_shardings(cache_shape_tree, mesh: Mesh, *,
                      sequence_parallel: bool = False):
    """GQA cache [L,B,S,Hkv,Dh] / MLA cache [L,B,S,lora].

    decode_32k: shard batch over DP (+ kv heads over model if divisible).
    long_500k (sequence_parallel=True): shard the S axis over `model` —
    flash-decode-style split-S softmax, combined by GSPMD's partitioner.
    """
    dp = dp_axes(mesh)

    def leaf(l):
        shape = l.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        if len(shape) >= 3:
            if sequence_parallel and "model" in mesh.axis_names \
                    and _fits(shape[2], mesh, "model"):
                spec[2] = "model"
            if _fits(shape[1], mesh, dp):
                spec[1] = dp
            if (not sequence_parallel and len(shape) >= 5
                    and "model" in mesh.axis_names
                    and _fits(shape[3], mesh, "model")):
                spec[3] = "model"    # kv heads over TP when they fit
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(leaf, cache_shape_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# HLO inspection helpers (roofline: collective bytes from lowered text)
# ---------------------------------------------------------------------------

_RESULT_TYPE_RE = re.compile(r"=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_OP_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in an (optimized, post-SPMD)
    HLO dump. Returns {op_kind: bytes}.

    HLO lines read `%name = f32[16,1024]{1,0} all-gather(...)` — result
    type precedes the op. Async `-done` ops are skipped (their `-start`
    twin already carries the payload)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        op = _COLLECTIVE_OP_RE.search(line)
        if not op or op.group(2) == "-done":
            continue
        m = _RESULT_TYPE_RE.search(line)
        if not m:
            continue
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        kind = op.group(1)
        out[kind] = out.get(kind, 0) + n * _DTYPE_BYTES.get(dtype, 4)
    return out
