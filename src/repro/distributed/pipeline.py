"""Pipeline parallelism: stage-sharded layer stacks with a 1F1B-style
microbatch rotation built on collective_permute.

For the deepest configs (61-layer MoEs) pipeline parallelism trades the
all-layer FSDP all-gathers for point-to-point boundary transfers. The
mesh axis used for stages is the existing `model` axis — inside a
shard_map, each device along it owns n_layers/S contiguous layers and the
microbatch stream rotates through stages with lax.ppermute:

  stage s at step t runs microbatch (t - s); after n_micro + S - 1 steps
  every microbatch has crossed every stage (classic GPipe fill+drain, no
  1F1B interleave of fwd/bwd — the backward pipeline reverses the ring).

`pipeline_forward` is jit/shard_map-compatible and exact: outputs equal
running the layers sequentially (tests/test_pipeline.py asserts this).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...] stage-major."""
    def leaf(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree.map(leaf, stacked_params)


def _stage_apply(body: Callable, stage_params, x, extra):
    """Run this device's layer slice sequentially (local scan)."""
    def step(carry, lp):
        return body(lp, carry, extra), None
    y, _ = jax.lax.scan(step, x, stage_params)
    return y


def pipeline_forward(body: Callable, stage_params, x_micro, *, extra=None,
                     axis_name: str = "model"):
    """Run microbatches through pipeline stages along `axis_name`.

    Inside shard_map: stage_params [1, L/S, ...] (this device's slice),
    x_micro [n_micro_local ... actually full [M, mb, ...] replicated].
    Returns [M, mb, ...] outputs after all stages.

    The rotation: maintain a buffer of M+S-1 slots; at step t, this stage
    (index s) processes slot t if s <= t < s + M; boundaries move by
    ppermute(s -> s+1) after every step.
    """
    S = jax.lax.psum(1, axis_name)
    s_idx = jax.lax.axis_index(axis_name)
    M = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    n_steps = M + S - 1

    perm = [(i, i + 1) for i in range(S - 1)]

    def step(carry, t):
        # carry: (cur [mb...] — the activation currently at this stage,
        #         outputs [M, mb...])
        cur, outputs = carry
        # stage 0 injects microbatch t (if valid) from the replicated input
        inject = jnp.where(t < M, t, 0)
        x_in = x_micro[inject]
        cur = jnp.where(s_idx == 0, x_in, cur)

        active = (t >= s_idx) & (t < s_idx + M)
        y = _stage_apply(body, jax.tree.map(lambda p: p[0], stage_params),
                         cur, extra)
        y = jnp.where(active, y, cur)

        # the last stage writes finished microbatch (t - S + 1)
        out_idx = t - (S - 1)
        write = (s_idx == S - 1) & (out_idx >= 0)
        safe = jnp.where(out_idx >= 0, out_idx, 0)
        outputs = jnp.where(
            write,
            outputs.at[safe].set(y),
            outputs)

        # rotate boundary activations one stage forward
        nxt = jax.lax.ppermute(y, axis_name, perm)
        return (nxt, outputs), None

    outputs0 = jnp.zeros((M,) + mb_shape, x_micro.dtype)
    cur0 = jnp.zeros(mb_shape, x_micro.dtype)
    (_, outputs), _ = jax.lax.scan(
        step, (cur0, outputs0), jnp.arange(n_steps))
    # only the last stage holds real outputs; broadcast via masked psum
    outputs = jax.lax.psum(
        jnp.where(s_idx == S - 1, outputs, jnp.zeros_like(outputs)),
        axis_name)
    return outputs


def make_pipelined_forward(body: Callable, mesh: Mesh, n_stages: int, *,
                           axis_name: str = "model"):
    """Wrap a layer body into a pjit-able pipelined forward.

    Returns fn(stage_params [S, L/S, ...], x_micro [M, mb, ...], extra).
    """
    def fn(stage_params, x_micro, extra=None):
        return pipeline_forward(body, stage_params, x_micro, extra=extra,
                                axis_name=axis_name)

    return shard_map(fn, mesh=mesh,
                     in_specs=(P(axis_name), P(), P()),
                     out_specs=P(), check_rep=False)
