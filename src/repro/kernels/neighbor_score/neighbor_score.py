"""Batched candidate-neighbor scoring Pallas kernel (paper §3.3).

The fleet controller's shape-evolution inner loop scores every lattice
neighbor of a head orientation against the bbox geometry of the current
shape — one [N, N] reduction per camera per loop iteration, repeated up to
~n_cells times per timestep for every camera in the fleet. This kernel
fuses the whole fleet batch: grid = (B / block_b,); each step loads a
(block_b, Np) strip of per-camera state plus the (broadcast) [Np, Np]
grid geometry and emits (block_b, Np) scores.

All arrays are padded to Np = 128 cells (one f32 lane tile) by ops.py;
padded cells carry member_has = 0 so they contribute nothing and score
the neutral 1.0, which the candidate mask filters out. Per grid step the
working set is 3 * (block_b, 128) strips + 4 static (128, 128) matrices
+ a (block_b, 128, 128) broadcast intermediate — ~4.3 MB f32 at
block_b = 64, comfortably inside VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(mh_ref, cx_ref, cy_ref, dcen_ref, ovl_ref, gx_ref, gy_ref,
                  o_ref):
    mh = mh_ref[...].astype(jnp.float32)         # [bb, Np] member & has
    cx = cx_ref[...].astype(jnp.float32)         # [bb, Np] centroid x
    cy = cy_ref[...].astype(jnp.float32)
    dcen = dcen_ref[...].astype(jnp.float32)     # [Np, Np] |center_c-center_o|
    ovl = ovl_ref[...].astype(jnp.float32)       # [Np, Np] FOV overlap
    gx = gx_ref[...].astype(jnp.float32)         # [Np, Np] cell_x[c] bcast
    gy = gy_ref[...].astype(jnp.float32)

    w = ovl[None, :, :] * mh[:, None, :]                         # [bb, c, o]
    dx = gx[None, :, :] - cx[:, None, :]
    dy = gy[None, :, :] - cy[:, None, :]
    d_box = jnp.sqrt(dx * dx + dy * dy)
    ratio = dcen[None, :, :] / jnp.maximum(d_box, 1e-6)
    total = jnp.sum(w * ratio, axis=-1)                          # [bb, c]
    total_w = jnp.sum(w, axis=-1)
    score = jnp.where(total_w > 0.0,
                      total / jnp.maximum(total_w, 1e-9), 1.0)
    o_ref[...] = score.astype(o_ref.dtype)


def neighbor_score_batch(member_has: jnp.ndarray, cent_x: jnp.ndarray,
                         cent_y: jnp.ndarray, d_center: jnp.ndarray,
                         overlap: jnp.ndarray, grid_x: jnp.ndarray,
                         grid_y: jnp.ndarray, *, block_b: int = 64,
                         interpret: bool = True) -> jnp.ndarray:
    """member_has/cent_x/cent_y [B, Np]; d_center/overlap/grid_x/grid_y
    [Np, Np]. B must be a multiple of block_b (ops.py pads). -> [B, Np]."""
    B, Np = member_has.shape
    grid = (B // block_b,)
    strip = pl.BlockSpec((block_b, Np), lambda i: (i, 0))
    full = pl.BlockSpec((Np, Np), lambda i: (0, 0))
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[strip, strip, strip, full, full, full, full],
        out_specs=strip,
        out_shape=jax.ShapeDtypeStruct((B, Np), jnp.float32),
        interpret=interpret,
    )(member_has, cent_x, cent_y, d_center, overlap, grid_x, grid_y)
