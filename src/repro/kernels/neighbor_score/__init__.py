from repro.kernels.neighbor_score import ops, ref
from repro.kernels.neighbor_score.ops import geometry_arrays, neighbor_scores
