"""jit'd wrappers: fleet-batched neighbor scoring with padding + dispatch.

`neighbor_scores` accepts the controller-native layout (shape mask, per-cell
centroids, head cell per camera) and returns (scores, candidate mask) over
the un-padded grid. The heavy [B, N, N] reduction dispatches to the Pallas
kernel (padded to 128 lanes) or to the pure-jnp reference — the reference
path is the default inside fused fleet steps (XLA fuses it into the
surrounding program), the kernel path is for TPU serving where the scoring
batch dominates (set REPRO_NEIGHBOR_KERNEL=1 or pass use_kernel=True).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.neighbor_score.neighbor_score import neighbor_score_batch
from repro.kernels.neighbor_score.ref import neighbor_scores_ref
from repro.obs import span

LANES = 128


def geometry_arrays(grid) -> dict:
    """Static per-grid geometry (numpy) consumed by the scorer.

    d_center/overlap are [N, N]; neighbor8 is the 8-connected candidate
    adjacency; cell_x/cell_y are [N] centers. Cached by the caller
    (repro.fleet.state builds it once per FleetStatics).
    """
    centers = np.asarray(grid.centers, np.float32)
    d_center = np.linalg.norm(
        centers[:, None, :] - centers[None, :, :], axis=-1
    ).astype(np.float32)
    return {
        "d_center": d_center,
        "overlap": np.asarray(grid.overlap_matrix, np.float32),
        "neighbor8": np.asarray(grid.neighbor_mask, bool),
        "cell_x": centers[:, 0].copy(),
        "cell_y": centers[:, 1].copy(),
    }


def _pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def neighbor_scores(shape_mask: jnp.ndarray, has_boxes: jnp.ndarray,
                    centroids: jnp.ndarray, head: jnp.ndarray,
                    d_center: jnp.ndarray, overlap: jnp.ndarray,
                    cell_x: jnp.ndarray, cell_y: jnp.ndarray,
                    neighbor8: jnp.ndarray, *, use_kernel: bool = False,
                    interpret: bool = True, block_b: int = 64):
    """shape_mask/has_boxes [B, N] bool; centroids [B, N, 2]; head [B] int;
    geometry [N, N] / [N]. -> (scores [B, N] f32, cand [B, N] bool).

    Scores match core/neighbor.score_candidates on candidate cells;
    non-candidates are scored too (same formula) and masked by `cand`.
    The env override is resolved here, outside the jit cache, so flipping
    REPRO_NEIGHBOR_KERNEL between calls selects the right executable.
    """
    use_kernel = (use_kernel
                  or os.environ.get("REPRO_NEIGHBOR_KERNEL", "") == "1")
    # host span: times trace/dispatch at this entry point (execution is
    # async); a no-op unless a repro.obs tracer is active
    with span("ops/neighbor_scores", b=int(shape_mask.shape[0]),
              use_kernel=use_kernel):
        return _neighbor_scores(shape_mask, has_boxes, centroids, head,
                                d_center, overlap, cell_x, cell_y,
                                neighbor8, use_kernel=use_kernel,
                                interpret=interpret, block_b=block_b)


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_b"))
def _neighbor_scores(shape_mask, has_boxes, centroids, head, d_center,
                     overlap, cell_x, cell_y, neighbor8, *,
                     use_kernel: bool, interpret: bool, block_b: int):
    B, N = shape_mask.shape
    member_has = (shape_mask & has_boxes).astype(jnp.float32)
    cent_x = centroids[..., 0]
    cent_y = centroids[..., 1]

    if use_kernel:
        if N > LANES:
            raise ValueError(
                f"neighbor_score kernel supports up to {LANES} grid cells "
                f"(one lane tile), got {N}; use the reference path")
        Bp = -(-B // block_b) * block_b
        mh = jnp.pad(member_has, ((0, Bp - B), (0, LANES - N)))
        cx = jnp.pad(cent_x, ((0, Bp - B), (0, LANES - N)))
        cy = jnp.pad(cent_y, ((0, Bp - B), (0, LANES - N)))
        scores = neighbor_score_batch(
            mh, cx, cy,
            _pad2(d_center, LANES, LANES), _pad2(overlap, LANES, LANES),
            _pad2(jnp.broadcast_to(cell_x[:, None], (N, N)), LANES, LANES),
            _pad2(jnp.broadcast_to(cell_y[:, None], (N, N)), LANES, LANES),
            block_b=block_b, interpret=interpret)[:B, :N]
    else:
        scores = neighbor_scores_ref(member_has, cent_x, cent_y,
                                     d_center, overlap, cell_x, cell_y)
    cand = neighbor8[head] & ~shape_mask
    return scores, cand
