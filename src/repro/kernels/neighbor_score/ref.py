"""Pure-jnp oracle for batched candidate-neighbor scoring (paper §3.3).

Mirrors core/neighbor.score_candidates for a whole fleet at once: for a
batch of cameras, each with a shape mask, per-cell bbox centroids and a
head cell H, score every grid cell c as the overlap-weighted mean of

    ratio(c, o) = dist(center_c, center_o) / dist(center_c, centroid_o)

over shape members o with non-zero FOV overlap and boxes; cells with no
informative overlap get the neutral score 1.0. Candidate masking (lattice
neighbors of H not in the shape) is returned separately so the caller can
arg-max over candidates only.
"""
from __future__ import annotations

import jax.numpy as jnp


def neighbor_scores_ref(member_has: jnp.ndarray, cent_x: jnp.ndarray,
                        cent_y: jnp.ndarray, d_center: jnp.ndarray,
                        overlap: jnp.ndarray, cell_x: jnp.ndarray,
                        cell_y: jnp.ndarray) -> jnp.ndarray:
    """member_has [B, N] f32 — 1.0 where the cell is a shape member with
    boxes; cent_x/cent_y [B, N] — bbox centroid per cell (scene degrees,
    junk where member_has is 0); d_center/overlap [N, N] — pairwise cell
    center distance / FOV overlap; cell_x/cell_y [N] — cell centers.

    Returns scores [B, N] f32 for every cell as candidate.
    """
    w = overlap[None, :, :] * member_has[:, None, :]          # [B, c, o]
    dx = cell_x[None, :, None] - cent_x[:, None, :]
    dy = cell_y[None, :, None] - cent_y[:, None, :]
    d_box = jnp.sqrt(dx * dx + dy * dy)
    ratio = d_center[None, :, :] / jnp.maximum(d_box, 1e-6)
    total = jnp.sum(w * ratio, axis=-1)
    total_w = jnp.sum(w, axis=-1)
    return jnp.where(total_w > 0, total / jnp.maximum(total_w, 1e-9), 1.0)
