"""jit'd wrappers: candidate crops -> patch-embedding tokens.

`crop_patchify` accepts the provider-native layout (scene object boxes +
per-camera shortlisted FOV windows + the detector's conv patch-embed
params) and returns the [F, K, gg, D] token rows the batched detector
forward consumes. Like cell_rasterize, the pure-jnp reference is the
default inside fused fleet steps — on the reference path the pixels are
the existing `render_fleet_crops` output fed through the existing conv,
so it is bit-identical to the unfused pixel pipeline. The Pallas kernel
path (use_kernel=True, or REPRO_PATCHIFY_KERNEL=1) fuses rasterization
into the patch contraction so crops never round-trip through HBM as
pixels — the TPU serving path, equivalence-tested in interpret mode.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.crop_patchify.crop_patchify import crop_patchify_batch
from repro.kernels.crop_patchify.ref import crop_patchify_ref
from repro.obs import span

SUBLANES = 8


def crop_patchify(pos, size, kind, oid, windows, patch_params, *,
                  patch: int, res: int = 64, min_visible: float = 0.25,
                  noise=None, dtype=jnp.float32, block_k: int | None = None,
                  use_kernel: bool = False,
                  interpret: bool = True) -> jnp.ndarray:
    """pos/size [F, M, 2], kind [M], oid [F, M]; windows [F, K, 4] or
    [K, 4] fleet-shared; patch_params {"w": [p, p, 3, D], "b": [D]};
    noise [F, res, res, 3] or None. Returns tokens [F, K, (res/p)^2, D].

    `block_k` (reference path only; must divide K) slabs the K window
    axis so the transient pixel buffer peaks at [F, block_k, res, res,
    3] instead of all K crops at once — the jnp analogue of the
    kernel's per-block VMEM residency; tokens come out identical
    because each crop's render+embed is independent. The Pallas path
    already blocks per (camera, window) and ignores it.

    The env override is resolved when this wrapper traces — inside an
    enclosing jit (the episode scan) the branch is baked in at that
    program's first trace; flip the kernel path via the provider's
    use_kernel field there.
    """
    use_kernel = (use_kernel
                  or os.environ.get("REPRO_PATCHIFY_KERNEL", "") == "1")
    if res % patch != 0:
        raise ValueError(f"res={res} must be a multiple of patch={patch}")
    k = windows.shape[-2]
    if block_k is not None and (block_k <= 0 or k % block_k != 0):
        raise ValueError(f"block_k={block_k} must divide the {k} windows")
    # host span: times trace/dispatch at this entry point (execution is
    # async); a no-op unless a repro.obs tracer is active
    with span("ops/crop_patchify", k=k, use_kernel=use_kernel):
        return _crop_patchify(pos, size, kind, oid, windows, patch_params,
                              noise, patch=patch, res=res,
                              min_visible=min_visible, dtype=dtype,
                              block_k=block_k, use_kernel=use_kernel,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("patch", "res", "min_visible", "dtype",
                                   "block_k", "use_kernel", "interpret"))
def _crop_patchify(pos, size, kind, oid, windows, patch_params, noise, *,
                   patch: int, res: int, min_visible: float, dtype,
                   block_k: int | None, use_kernel: bool,
                   interpret: bool) -> jnp.ndarray:
    if not use_kernel:
        ref = partial(crop_patchify_ref, pos, size, kind, oid,
                      patch_params=patch_params, patch=patch, res=res,
                      min_visible=min_visible, noise=noise, dtype=dtype)
        k = windows.shape[-2]
        if block_k is None or block_k >= k:
            return ref(windows=windows)
        # slab the window axis: the serial dimension only covers the
        # cheap render+embed; callers still batch the expensive model
        # forward over all K at once
        wblocks = jnp.moveaxis(
            windows.reshape(windows.shape[:-2]
                            + (k // block_k, block_k, 4)), -3, 0)
        tok = jax.lax.map(lambda wb: ref(windows=wb), wblocks)
        return jnp.moveaxis(tok, 0, 1).reshape(
            (tok.shape[1], k) + tok.shape[3:])
    from repro.scene_jax.render import object_colors, render_background

    f, m = oid.shape
    if windows.ndim == 2:
        windows = jnp.broadcast_to(windows[None], (f,) + windows.shape)
    mp = -(-m // SUBLANES) * SUBLANES
    pad = [(0, 0), (0, mp - m)]
    # padded slots carry ow = oh = 0 -> zero visibility, never painted
    ox = jnp.pad(pos[..., 0], pad)
    oy = jnp.pad(pos[..., 1], pad)
    ow = jnp.pad(size[..., 0], pad)
    oh = jnp.pad(size[..., 1], pad)
    col = object_colors(kind, oid)                      # [F, M, 3]
    col = jnp.pad(col, pad + [(0, 0)]).astype(jnp.float32)
    bgn = render_background(res)[None]
    if noise is not None:
        bgn = bgn + noise
    bgn = jnp.broadcast_to(bgn, (f, res, res, 3)).astype(jnp.float32)
    wflat = patch_params["w"].astype(jnp.float32).reshape(
        patch * patch * 3, -1)
    bias = patch_params.get("b")
    bias = (jnp.zeros((1, wflat.shape[1]), jnp.float32) if bias is None
            else bias.astype(jnp.float32)[None])
    tok = crop_patchify_batch(
        ox.astype(jnp.float32), oy.astype(jnp.float32),
        ow.astype(jnp.float32), oh.astype(jnp.float32),
        col[..., 0], col[..., 1], col[..., 2],
        windows.astype(jnp.float32), bgn, wflat, bias,
        res=res, patch=patch, min_visible=min_visible,
        interpret=interpret)
    return tok.astype(dtype)
