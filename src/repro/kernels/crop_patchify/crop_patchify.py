"""Pallas kernel: fused rasterize -> ViT patch-embed for candidate crops.

The detector-in-step fast path scores [F, K] shortlisted candidate
windows per controller timestep. Unfused, every crop is rendered to an
HBM pixel buffer ([F, K, res, res, 3] — at 256 cameras x 75 windows x
64 px that is ~0.9 GB per step) only to be immediately contracted down
to [F, K, gg, D] patch embeddings by the backbone's patch-embed conv.
This kernel fuses the two: each grid step (one (camera, window) pair)
paints the crop in VMEM — same last-painter-wins/visibility/rounding
rules as scene_jax.render.render_crop — and contracts the patch tiles
against the flattened patch-embed weights on the spot, so candidate
crops never round-trip through HBM as pixels; only the ~res^2/p^2 x D
token rows are written out.

Per grid step the dominant working set is the [Mp, res, res] ownership
intermediates: ~2 MB int32/bool at Mp = 32 objects, res = 64 — well
under VMEM next to the [res, res, 3] crop (48 KB) and the
[p*p*3, D] weight tile. ops.py precomputes the per-object paint colors
and the background+noise plane so the kernel body is pure geometry +
one [gg, p*p*3] x [p*p*3, D] matmul (MXU-shaped once D, p*p*3 reach
128; the smoke config underfills the tile but the layout is right).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(res: int, patch: int, min_visible: float):
    g = res // patch

    def kernel(ox_ref, oy_ref, ow_ref, oh_ref, cr_ref, cg_ref, cb_ref,
               win_ref, bgn_ref, w_ref, b_ref, out_ref):
        ox = ox_ref[0].astype(jnp.float32)           # [Mp]
        oy = oy_ref[0].astype(jnp.float32)
        ow = ow_ref[0].astype(jnp.float32)
        oh = oh_ref[0].astype(jnp.float32)
        x0 = win_ref[0, 0, 0]
        y0 = win_ref[0, 0, 1]
        fw = win_ref[0, 0, 2]
        fh = win_ref[0, 0, 3]

        ox0 = ox - ow * 0.5
        ox1 = ox + ow * 0.5
        oy0 = oy - oh * 0.5
        oy1 = oy + oh * 0.5
        ix0 = jnp.maximum(ox0, x0)
        ix1 = jnp.minimum(ox1, x0 + fw)
        iy0 = jnp.maximum(oy0, y0)
        iy1 = jnp.minimum(oy1, y0 + fh)
        inter = (jnp.maximum(ix1 - ix0, 0.0)
                 * jnp.maximum(iy1 - iy0, 0.0))
        area = (ox1 - ox0) * (oy1 - oy0)
        keep = inter / jnp.maximum(area, 1e-9) >= min_visible

        # normalized clipped box -> pixel bounds (render_crop's rounding:
        # clip first, then truncate — everything non-negative)
        px0 = jnp.clip((ix0 - x0) / fw * res, 0, res - 1).astype(jnp.int32)
        px1 = jnp.clip((ix1 - x0) / fw * res + 1, 1, res).astype(jnp.int32)
        py0 = jnp.clip((iy0 - y0) / fh * res, 0, res - 1).astype(jnp.int32)
        py1 = jnp.clip((iy1 - y0) / fh * res + 1, 1, res).astype(jnp.int32)

        mp = ox.shape[0]
        rr = jax.lax.broadcasted_iota(jnp.int32, (mp, res, res), 1)
        cc = jax.lax.broadcasted_iota(jnp.int32, (mp, res, res), 2)
        mi = jax.lax.broadcasted_iota(jnp.int32, (mp, res, res), 0)
        hit = (keep[:, None, None]
               & (rr >= py0[:, None, None]) & (rr < py1[:, None, None])
               & (cc >= px0[:, None, None]) & (cc < px1[:, None, None]))
        m_best = jnp.max(jnp.where(hit, mi, -1), axis=0)    # [res, res]
        sel = jnp.maximum(m_best, 0)
        painted = m_best >= 0

        bgn = bgn_ref[0].astype(jnp.float32)         # [res, res, 3]
        img = jnp.stack([
            jnp.where(painted, cr_ref[0][sel], bgn[..., 0]),
            jnp.where(painted, cg_ref[0][sel], bgn[..., 1]),
            jnp.where(painted, cb_ref[0][sel], bgn[..., 2]),
        ], axis=-1)
        img = jnp.clip(img, 0.0, 1.0)

        # [res, res, 3] -> [gg, p*p*3] patch rows, (row, col, chan) fast
        # axis order matching the HWIO conv weight flatten in ops.py
        tiles = img.reshape(g, patch, g, patch, 3)
        tiles = tiles.transpose(0, 2, 1, 3, 4).reshape(
            g * g, patch * patch * 3)
        tok = jnp.dot(tiles, w_ref[...],
                      preferred_element_type=jnp.float32)
        out_ref[0, 0] = tok + b_ref[0]

    return kernel


def crop_patchify_batch(ox, oy, ow, oh, col_r, col_g, col_b, wins, bgn,
                        wflat, bias, *, res: int, patch: int,
                        min_visible: float = 0.25,
                        interpret: bool = True) -> jnp.ndarray:
    """ox/oy/ow/oh/col_* [F, Mp] object strips + paint colors (padded
    slots carry ow = oh = 0: never visible); wins [F, K, 4] per-camera
    FOV windows; bgn [F, res, res, 3] background + noise plane; wflat
    [p*p*3, D] flattened patch-embed weights; bias [1, D]. Returns
    tokens [F, K, (res/p)^2, D] float32."""
    f, mp = ox.shape
    k = wins.shape[1]
    gg = (res // patch) ** 2
    d = wflat.shape[1]
    strip = pl.BlockSpec((1, mp), lambda i, j: (i, 0))
    win = pl.BlockSpec((1, 1, 4), lambda i, j: (i, j, 0))
    plane = pl.BlockSpec((1, res, res, 3), lambda i, j: (i, 0, 0, 0))
    wspec = pl.BlockSpec(wflat.shape, lambda i, j: (0, 0))
    bspec = pl.BlockSpec(bias.shape, lambda i, j: (0, 0))
    out = pl.BlockSpec((1, 1, gg, d), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _make_kernel(res, patch, min_visible),
        grid=(f, k),
        in_specs=[strip, strip, strip, strip, strip, strip, strip,
                  win, plane, wspec, bspec],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((f, k, gg, d), jnp.float32),
        interpret=interpret,
    )(ox, oy, ow, oh, col_r, col_g, col_b, wins, bgn, wflat, bias)
