"""Pure-jnp oracle: candidate crops -> ViT patch-embedding tokens.

The reference composes the two stages the fused Pallas kernel replaces —
rasterize every (camera, window) crop, then apply the detector
backbone's conv patch-embed (`models.vit.vit_embed`'s conv, stride =
patch, VALID) — and is **bit-identical** to
`render_fleet_crops` + `conv2d` (pinned by array_equal in
tests/test_kernels.py): the float pipeline (visibility cut, pixel-bound
rounding, class colors and oid shades, background + noise, clip, the
conv itself) is op-for-op the renderer's.

What it does NOT share is the renderer's O(M * res^2) ownership
reduction. Last-painter-wins ownership is pure integer logic — the
winning painter of a pixel is the highest object index whose clipped
rect covers it — so for M <= 32 objects the per-object row/column
interval masks pack into one uint32 lane and ownership becomes a single
AND + count-leading-zeros per pixel (m_best = 31 - clz(row & col),
which is exactly -1 on empty masks since clz(0) = 32). Same integer
winner -> same gathered color -> bit-identical pixels, at ~M times less
ownership work — this is where the fused fast path's crop->token stage
beats the retained chunked reference on any backend, before the Pallas
kernel's VMEM residency is even in play.

The pixels still materialize here ([F, K, res, res, 3] between the
stages — ops.py's block_k bounds the transient); the Pallas kernel is
the path where they never leave VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import conv2d
from repro.scene_jax.render import (
    object_colors,
    render_background,
    render_fleet_crops,
)

_PACK_MAX = 32      # object slots per uint32 ownership lane


def _render_crops_packed(pos, size, kind, oid, windows, noise, *,
                         res: int, min_visible: float) -> jnp.ndarray:
    """Bit-identical render_fleet_crops for M <= 32 object slots.

    pos/size [F, M, 2], oid [F, M], windows [F, K, 4] or [K, 4] shared,
    noise [F, res, res, 3] or None -> [F, K, res, res, 3].
    """
    if windows.ndim == 2:
        windows = jnp.broadcast_to(
            windows[None], (pos.shape[0],) + windows.shape)
    m = pos.shape[1]
    x0 = windows[..., 0][..., None]                 # [F, K, 1]
    y0 = windows[..., 1][..., None]
    fw = windows[..., 2][..., None]
    fh = windows[..., 3][..., None]
    ox0 = (pos[..., 0] - size[..., 0] / 2)[:, None]  # [F, 1, M]
    ox1 = (pos[..., 0] + size[..., 0] / 2)[:, None]
    oy0 = (pos[..., 1] - size[..., 1] / 2)[:, None]
    oy1 = (pos[..., 1] + size[..., 1] / 2)[:, None]

    # visibility + pixel bounds: render_crop's float math, verbatim
    ix0 = jnp.maximum(ox0, x0)
    ix1 = jnp.minimum(ox1, x0 + fw)
    iy0 = jnp.maximum(oy0, y0)
    iy1 = jnp.minimum(oy1, y0 + fh)
    inter = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
    area = (ox1 - ox0) * (oy1 - oy0)
    keep = inter / jnp.maximum(area, 1e-9) >= min_visible

    px0 = jnp.clip((ix0 - x0) / fw * res, 0, res - 1).astype(jnp.int32)
    px1 = jnp.clip((ix1 - x0) / fw * res + 1, 1, res).astype(jnp.int32)
    py0 = jnp.clip((iy0 - y0) / fh * res, 0, res - 1).astype(jnp.int32)
    py1 = jnp.clip((iy1 - y0) / fh * res + 1, 1, res).astype(jnp.int32)

    # pack each object's row/col interval into its uint32 bit lane;
    # ownership = highest set bit of (rowbits & colbits) per pixel
    lane = (jnp.uint32(1) << jnp.arange(m, dtype=jnp.uint32))
    rc = jnp.arange(res)
    rows = (keep[..., None]
            & (rc >= py0[..., None]) & (rc < py1[..., None]))
    cols = (keep[..., None]
            & (rc >= px0[..., None]) & (rc < px1[..., None]))
    rowbits = jnp.sum(rows * lane[:, None], axis=-2, dtype=jnp.uint32)
    colbits = jnp.sum(cols * lane[:, None], axis=-2, dtype=jnp.uint32)
    bits = rowbits[..., :, None] & colbits[..., None, :]  # [F, K, r, r]
    m_best = 31 - jax.lax.clz(bits).astype(jnp.int32)     # clz(0) -> -1

    color = object_colors(kind, oid)                      # [F, M, 3]
    img = render_background(res)
    if noise is not None:
        img = img[None] + noise                           # [F, r, r, 3]
        img = img[:, None]
    painted = jax.vmap(lambda c, s: c[s])(
        color, jnp.maximum(m_best, 0))                    # [F, K, r, r, 3]
    img = jnp.where((m_best >= 0)[..., None], painted, img)
    return jnp.clip(img, 0.0, 1.0)


def crop_patchify_ref(pos, size, kind, oid, windows, patch_params, *,
                      patch: int, res: int = 64,
                      min_visible: float = 0.25, noise=None,
                      dtype=jnp.float32) -> jnp.ndarray:
    """pos/size [F, M, 2], kind [M], oid [F, M]; windows [F, K, 4] (or
    [K, 4] fleet-shared); patch_params the conv patch-embed pytree
    ({"w": [p, p, 3, D], "b": [D]}); noise [F, res, res, 3] or None.
    Returns patch-embedding tokens [F, K, (res/p)^2, D] in `dtype` —
    `models.vit.vit_encode_tokens` input layout.
    """
    if pos.shape[1] <= _PACK_MAX:
        crops = _render_crops_packed(pos, size, kind, oid, windows,
                                     noise, res=res,
                                     min_visible=min_visible)
    else:
        crops = render_fleet_crops(pos, size, kind, oid, windows,
                                   res=res, min_visible=min_visible,
                                   noise=noise)
    f, k = crops.shape[:2]
    x = conv2d(patch_params, crops.reshape((f * k, res, res, 3))
               .astype(dtype), stride=patch, padding="VALID")
    return x.reshape(f, k, -1, x.shape[-1])
