from repro.kernels.crop_patchify import ops, ref
from repro.kernels.crop_patchify.ops import crop_patchify
