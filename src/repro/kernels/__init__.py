"""Pallas TPU kernels (validated in interpret mode on CPU).

  flash_attention/  tiled online-softmax attention (causal/GQA)
  box_iou/          dense pairwise IoU + static-shape NMS/matching
  rmsnorm/          fused RMSNorm
  frame_delta/      tile-based frame delta encoder (MadEye transmission)
  neighbor_score/   fleet-batched candidate-neighbor scoring (shape search)
  cell_rasterize/   boxes -> cells x zooms aggregation (scene substrate)
  crop_patchify/    fused rasterize -> ViT patch-embed for candidate
                    crops (detector-in-step fast path; pixels stay in
                    VMEM)

Each kernel package ships `<name>.py` (pl.pallas_call + BlockSpec),
`ops.py` (jit'd public wrapper) and `ref.py` (pure-jnp oracle used by the
per-kernel allclose sweeps in tests/).
"""
