"""Pure-jnp oracle for box IoU + the NMS / matching consumers."""
from __future__ import annotations

import jax.numpy as jnp


def cxcywh_to_corners(b: jnp.ndarray):
    x0 = b[..., 0] - b[..., 2] * 0.5
    y0 = b[..., 1] - b[..., 3] * 0.5
    x1 = b[..., 0] + b[..., 2] * 0.5
    y1 = b[..., 1] + b[..., 3] * 0.5
    return x0, y0, x1, y1


def box_iou_ref(boxes_a: jnp.ndarray, boxes_b: jnp.ndarray) -> jnp.ndarray:
    """[N,4] x [M,4] cxcywh -> [N,M] IoU (f32)."""
    ax0, ay0, ax1, ay1 = cxcywh_to_corners(boxes_a.astype(jnp.float32))
    bx0, by0, bx1, by1 = cxcywh_to_corners(boxes_b.astype(jnp.float32))
    ix0 = jnp.maximum(ax0[:, None], bx0[None, :])
    iy0 = jnp.maximum(ay0[:, None], by0[None, :])
    ix1 = jnp.minimum(ax1[:, None], bx1[None, :])
    iy1 = jnp.minimum(ay1[:, None], by1[None, :])
    inter = jnp.maximum(ix1 - ix0, 0.0) * jnp.maximum(iy1 - iy0, 0.0)
    area_a = (ax1 - ax0) * (ay1 - ay0)
    area_b = (bx1 - bx0) * (by1 - by0)
    union = area_a[:, None] + area_b[None, :] - inter
    return inter / jnp.maximum(union, 1e-9)
