"""jit'd wrappers: padded IoU matrix, static-shape greedy NMS, box matching.

All consumers keep static shapes: NMS returns a keep-mask (no compaction),
matching returns per-row best indices + validity — TPU-friendly, and the
shapes stay identical across timesteps so serving loops stay jit-stable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.box_iou.box_iou import box_iou_matrix


def _pad_rows(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


@partial(jax.jit, static_argnames=("block", "interpret"))
def box_iou(boxes_a: jnp.ndarray, boxes_b: jnp.ndarray, *, block: int = 128,
            interpret: bool = True) -> jnp.ndarray:
    """[N,4] x [M,4] cxcywh -> [N,M] IoU; any N/M (padded internally)."""
    N, M = boxes_a.shape[0], boxes_b.shape[0]
    bn = min(block, max(8, 1 << (N - 1).bit_length()))
    bm = min(block, max(8, 1 << (M - 1).bit_length()))
    a = _pad_rows(boxes_a, bn)
    b = _pad_rows(boxes_b, bm)
    out = box_iou_matrix(a, b, block_n=bn, block_m=bm, interpret=interpret)
    return out[:N, :M]


@partial(jax.jit, static_argnames=("interpret",))
def nms_mask(boxes: jnp.ndarray, scores: jnp.ndarray, valid: jnp.ndarray, *,
             iou_thresh: float = 0.5, interpret: bool = True) -> jnp.ndarray:
    """Greedy NMS over a static box budget.

    boxes [N,4] cxcywh, scores [N], valid [N] bool -> keep mask [N] bool.
    Iterates exactly N times (lax.fori_loop); each round picks the highest
    remaining score and suppresses overlaps >= iou_thresh.
    """
    N = boxes.shape[0]
    iou = box_iou(boxes, boxes, interpret=interpret)

    def body(_, state):
        keep, alive = state
        masked = jnp.where(alive, scores, -jnp.inf)
        i = jnp.argmax(masked)
        any_alive = jnp.any(alive)
        keep = keep.at[i].set(jnp.where(any_alive, True, keep[i]))
        overlap = iou[i] >= iou_thresh
        alive = jnp.where(any_alive, alive & ~overlap & ~(jnp.arange(N) == i),
                          alive)
        return keep, alive

    keep0 = jnp.zeros((N,), bool)
    alive0 = valid & (scores > 0)
    keep, _ = jax.lax.fori_loop(0, N, body, (keep0, alive0))
    return keep & valid


@partial(jax.jit, static_argnames=("interpret",))
def match_boxes(pred: jnp.ndarray, gt: jnp.ndarray, gt_valid: jnp.ndarray, *,
                iou_thresh: float = 0.5, interpret: bool = True):
    """Greedy one-to-one matching (mAP-style TP assignment).

    pred [N,4] (sorted by score desc), gt [M,4], gt_valid [M] ->
    (is_tp [N] bool, matched_gt [N] int32 (-1 if none)).
    """
    N, M = pred.shape[0], gt.shape[0]
    iou = box_iou(pred, gt, interpret=interpret)
    iou = jnp.where(gt_valid[None, :], iou, -1.0)

    def body(i, state):
        taken, is_tp, match = state
        row = jnp.where(taken, -1.0, iou[i])
        j = jnp.argmax(row)
        ok = row[j] >= iou_thresh
        taken = taken.at[j].set(taken[j] | ok)
        is_tp = is_tp.at[i].set(ok)
        match = match.at[i].set(jnp.where(ok, j, -1))
        return taken, is_tp, match

    state = (jnp.zeros((M,), bool), jnp.zeros((N,), bool),
             jnp.full((N,), -1, jnp.int32))
    _, is_tp, match = jax.lax.fori_loop(0, N, body, state)
    return is_tp, match
