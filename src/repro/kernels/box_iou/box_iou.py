"""Dense pairwise box-IoU Pallas kernel.

MadEye's detection post-processing (mAP scoring, cross-orientation dedup,
NMS) is dominated by the [N, M] IoU matrix. On GPU the paper leans on
cv2/torchvision NMS with dynamic shapes; the TPU adaptation is a dense
static-shape IoU matrix in VMEM tiles followed by masked argmax/greedy
suppression in plain lax (see ops.py).

Boxes are cxcywh in [0,1]. Grid tiles the [N, M] output; each step loads a
(block_n, 4) strip of A and a (block_m, 4) strip of B — both tiny — and
computes a (block_n, block_m) IoU tile on the VPU. Block sizes default to
(128, 128) = one f32 VREG tile per lane group.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iou_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)       # [bn, 4]
    b = b_ref[...].astype(jnp.float32)       # [bm, 4]

    ax0 = a[:, 0] - a[:, 2] * 0.5
    ay0 = a[:, 1] - a[:, 3] * 0.5
    ax1 = a[:, 0] + a[:, 2] * 0.5
    ay1 = a[:, 1] + a[:, 3] * 0.5
    bx0 = b[:, 0] - b[:, 2] * 0.5
    by0 = b[:, 1] - b[:, 3] * 0.5
    bx1 = b[:, 0] + b[:, 2] * 0.5
    by1 = b[:, 1] + b[:, 3] * 0.5

    ix0 = jnp.maximum(ax0[:, None], bx0[None, :])
    iy0 = jnp.maximum(ay0[:, None], by0[None, :])
    ix1 = jnp.minimum(ax1[:, None], bx1[None, :])
    iy1 = jnp.minimum(ay1[:, None], by1[None, :])

    iw = jnp.maximum(ix1 - ix0, 0.0)
    ih = jnp.maximum(iy1 - iy0, 0.0)
    inter = iw * ih
    area_a = (ax1 - ax0) * (ay1 - ay0)
    area_b = (bx1 - bx0) * (by1 - by0)
    union = area_a[:, None] + area_b[None, :] - inter
    o_ref[...] = (inter / jnp.maximum(union, 1e-9)).astype(o_ref.dtype)


def box_iou_matrix(boxes_a: jnp.ndarray, boxes_b: jnp.ndarray, *,
                   block_n: int = 128, block_m: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """boxes_a [N,4], boxes_b [M,4] cxcywh -> IoU [N,M] f32.

    N/M must be multiples of the block sizes (ops.py pads).
    """
    N, M = boxes_a.shape[0], boxes_b.shape[0]
    grid = (N // block_n, M // block_m)
    return pl.pallas_call(
        _iou_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        interpret=interpret,
    )(boxes_a, boxes_b)
