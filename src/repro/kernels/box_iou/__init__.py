from repro.kernels.box_iou import ops, ref
from repro.kernels.box_iou.ops import box_iou, match_boxes, nms_mask
