from repro.kernels.rmsnorm import ops, ref
from repro.kernels.rmsnorm.ops import rmsnorm
