"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    """x [..., D], weight [D]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return y.astype(x.dtype)
