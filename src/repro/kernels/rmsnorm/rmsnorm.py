"""Fused RMSNorm Pallas kernel.

RMSNorm is memory-bound (2 reads + 1 write of the activation); fusing the
square-mean reduction, rsqrt, and scale into one VMEM pass avoids the extra
HBM round-trip XLA sometimes emits around the f32 upcast. Grid tiles rows;
each step holds a (block_t, D) activation tile + the [1, D] weight in VMEM.

For d_model up to 8192 and block_t=256, the tile is 8 MiB f32 — the wrapper
shrinks block_t for wide models to stay under the VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # [bt, D]
    w = w_ref[...].astype(jnp.float32)            # [1, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm_rows(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6,
                 block_t: int = 256, interpret: bool = True) -> jnp.ndarray:
    """x [T, D] (T multiple of block_t), weight [D] -> normalized [T, D]."""
    T, D = x.shape
    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
    )(x, weight.reshape(1, D))
