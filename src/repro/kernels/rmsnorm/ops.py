"""jit'd wrapper: arbitrary leading dims, row padding, VMEM-aware
block size."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_rows

_VMEM_BUDGET = 4 * 1024 * 1024  # bytes for the activation tile (f32)


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6,
            interpret: bool = True) -> jnp.ndarray:
    """x [..., D], weight [D] -> RMS-normalized, same shape/dtype."""
    D = x.shape[-1]
    lead = x.shape[:-1]
    T = 1
    for s in lead:
        T *= s
    xt = x.reshape(T, D)

    block_t = max(8, min(256, _VMEM_BUDGET // (4 * D)))
    # round block down to a power of two for clean tiling
    block_t = 1 << (block_t.bit_length() - 1)
    pad = (-T) % block_t
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    out = rmsnorm_rows(xt, weight, eps=eps, block_t=block_t,
                       interpret=interpret)
    return out[:T].reshape(*lead, D)
