from repro.kernels.frame_delta import ops, ref
from repro.kernels.frame_delta.ops import apply_delta, frame_delta
