"""Frame-delta encoder Pallas kernel (MadEye §3.3 "Transmitting images").

MadEye keeps the last image shared per orientation and transmits only the
delta (Salsify-style functional codec). The hot loop — per-tile change
detection + int8 residual quantization — is a pure VPU streaming workload:

  per (th, tw, C) tile:
    d        = cur - ref                       (f32)
    changed  = mean(|d|) > tau                 (scalar per tile)
    delta_q  = round(clip(d / s, -127, 127))   (int8, zeroed if unchanged)

The "bytes to send" estimate = #changed tiles * tile bytes is computed from
the per-tile mask by the ops.py wrapper. Tiles are (8, 128)-lane aligned
multiples so each kernel step is a handful of full-VREG ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(cur_ref, prev_ref, dq_ref, mask_ref, *, tau: float,
                  scale: float):
    cur = cur_ref[...].astype(jnp.float32)      # [th, tw, C]
    prev = prev_ref[...].astype(jnp.float32)
    d = cur - prev
    changed = jnp.mean(jnp.abs(d)) > tau
    q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
    dq_ref[...] = jnp.where(changed, q, jnp.zeros_like(q))
    mask_ref[0, 0] = changed.astype(jnp.int32)


def frame_delta_tiles(cur: jnp.ndarray, prev: jnp.ndarray, *,
                      tile_h: int = 16, tile_w: int = 128,
                      tau: float = 0.02, scale: float = 1.0 / 127.0,
                      interpret: bool = True):
    """cur/prev [H, W, C] (H % tile_h == 0, W % tile_w == 0).

    Returns (delta_q [H,W,C] int8, changed [H/th, W/tw] int32).
    """
    H, W, C = cur.shape
    gh, gw = H // tile_h, W // tile_w
    return pl.pallas_call(
        functools.partial(_delta_kernel, tau=tau, scale=scale),
        grid=(gh, gw),
        in_specs=[
            pl.BlockSpec((tile_h, tile_w, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tile_h, tile_w, C), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_h, tile_w, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, W, C), jnp.int8),
            jax.ShapeDtypeStruct((gh, gw), jnp.int32),
        ],
        interpret=interpret,
    )(cur, prev)
