"""Pure-jnp oracle for the frame-delta encoder."""
from __future__ import annotations

import jax.numpy as jnp


def frame_delta_ref(cur: jnp.ndarray, prev: jnp.ndarray, *, tile_h: int = 16,
                    tile_w: int = 128, tau: float = 0.02,
                    scale: float = 1.0 / 127.0):
    """Tile-wise delta quantization. cur/prev [H,W,C].

    Returns (delta_q [H,W,C] int8, changed [H/th, W/tw] int32).
    """
    H, W, C = cur.shape
    gh, gw = H // tile_h, W // tile_w
    d = cur.astype(jnp.float32) - prev.astype(jnp.float32)
    tiles = d.reshape(gh, tile_h, gw, tile_w, C).transpose(0, 2, 1, 3, 4)
    changed = (jnp.mean(jnp.abs(tiles), axis=(2, 3, 4)) > tau)  # [gh, gw]
    q = jnp.clip(jnp.round(tiles / scale), -127, 127).astype(jnp.int8)
    q = jnp.where(changed[:, :, None, None, None], q, jnp.zeros_like(q))
    delta_q = q.transpose(0, 2, 1, 3, 4).reshape(H, W, C)
    return delta_q, changed.astype(jnp.int32)
