"""jit'd wrapper: padding to tile multiples + bytes-to-send estimate."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.frame_delta.frame_delta import frame_delta_tiles


@partial(jax.jit, static_argnames=("tile_h", "tile_w", "tau", "scale",
                                   "interpret"))
def frame_delta(cur: jnp.ndarray, prev: jnp.ndarray, *, tile_h: int = 16,
                tile_w: int = 128, tau: float = 0.02,
                scale: float = 1.0 / 127.0, interpret: bool = True):
    """cur/prev [H,W,C] float in [0,1].

    Returns (delta_q [H,W,C] int8, changed [gh,gw] int32, bytes_est []).
    bytes_est = changed tiles * tile bytes (int8 payload) + 4-byte tile map.
    """
    H, W, C = cur.shape
    ph = (-H) % tile_h
    pw = (-W) % tile_w
    if ph or pw:
        cur = jnp.pad(cur, ((0, ph), (0, pw), (0, 0)))
        prev = jnp.pad(prev, ((0, ph), (0, pw), (0, 0)))
    dq, changed = frame_delta_tiles(cur, prev, tile_h=tile_h, tile_w=tile_w,
                                    tau=tau, scale=scale, interpret=interpret)
    tile_bytes = tile_h * tile_w * C  # int8
    bytes_est = jnp.sum(changed) * tile_bytes + changed.size // 8 + 4
    return dq[:H, :W], changed, bytes_est


@partial(jax.jit, static_argnames=("scale",))
def apply_delta(prev: jnp.ndarray, delta_q: jnp.ndarray, *,
                scale: float = 1.0 / 127.0) -> jnp.ndarray:
    """Decoder side: reconstruct cur ≈ prev + delta_q * scale."""
    return prev + delta_q.astype(jnp.float32) * scale
