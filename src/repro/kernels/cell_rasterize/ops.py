"""jit'd wrappers: padded dispatch for the boxes -> cells rasterization.

`cell_rasterize` accepts the scene-native layout (per-camera object boxes
+ per-pair detection draws + flattened orientation windows) and returns
the un-padded aggregates. Like neighbor_score, the pure-jnp reference is
the default inside fused fleet steps (XLA fuses it into the scan body);
the Pallas kernel path is for TPU serving where the rasterization batch
dominates (set REPRO_RASTERIZE_KERNEL=1 or pass use_kernel=True).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cell_rasterize.cell_rasterize import cell_rasterize_batch
from repro.kernels.cell_rasterize.ref import cell_rasterize_ref

LANES = 128
SUBLANES = 8


def window_arrays(grid, zoom_levels=(1.0, 2.0, 3.0)) -> np.ndarray:
    """[N * Z, 4] static FOV windows (x0, y0, fw, fh), cell-major —
    orientation c_flat = cell * Z + zoom_idx, matching the [N, Z] reshape
    the fleet observation tables use."""
    rows = []
    for cell in range(grid.n_cells):
        cx, cy = grid.centers[cell]
        for z in zoom_levels:
            fw, fh = grid.fov(z)
            rows.append((cx - fw / 2, cy - fh / 2, fw, fh))
    return np.asarray(rows, np.float32)


def cell_rasterize(ox, oy, ow, oh, draw, a0, a1, windows, *,
                   min_visible: float = 0.25, n_moment: int | None = None,
                   use_kernel: bool = False, interpret: bool = True,
                   block_b: int = 8):
    """ox/oy/ow/oh [B, M]; draw [B, P, M] (2.0 = never detect);
    a0/a1 [P]; windows [C, 4]. -> (cnt [B, P, C], area [B, P, C],
    wcx/wcy/wc2/ext [B, C]). Only the first `n_moment` pair channels
    (default: all) feed the geometry moments/extent — lets a caller stack
    extra count-only channels (e.g. teacher draws) onto one pass.

    The env override is resolved when this wrapper traces: at top level
    that is per call, but inside an enclosing jit (the scene episode
    scan) the branch is baked in at the *enclosing* program's first
    trace — flip the kernel path via SceneSpec.use_kernel there.
    """
    use_kernel = (use_kernel
                  or os.environ.get("REPRO_RASTERIZE_KERNEL", "") == "1")
    if n_moment is None:
        n_moment = a0.shape[0]
    return _cell_rasterize(ox, oy, ow, oh, draw, a0, a1, windows,
                           min_visible=min_visible, n_moment=n_moment,
                           use_kernel=use_kernel, interpret=interpret,
                           block_b=block_b)


def _pad_to(x: jnp.ndarray, sizes: tuple) -> jnp.ndarray:
    return jnp.pad(x, [(0, s - d) for s, d in zip(sizes, x.shape)])


@partial(jax.jit, static_argnames=("min_visible", "n_moment", "use_kernel",
                                   "interpret", "block_b"))
def _cell_rasterize(ox, oy, ow, oh, draw, a0, a1, windows, *,
                    min_visible: float, n_moment: int, use_kernel: bool,
                    interpret: bool, block_b: int):
    if not use_kernel:
        return cell_rasterize_ref(ox, oy, ow, oh, draw, a0, a1, windows,
                                  min_visible=min_visible,
                                  n_moment=n_moment)
    B, M = ox.shape
    P = a0.shape[0]
    C = windows.shape[0]
    if M > LANES or C > LANES:
        raise ValueError(
            f"cell_rasterize kernel supports up to {LANES} objects/"
            f"orientations per tile, got M={M}, C={C}; "
            "use the reference path")
    Bp = -(-B // block_b) * block_b
    Mp, Cp = LANES, LANES
    Pp = -(-P // SUBLANES) * SUBLANES
    strips = [_pad_to(x, (Bp, Mp)) for x in (ox, oy, ow, oh)]
    # padded pairs/objects: draw = 2.0 can never beat a response in [0, 1]
    drawp = jnp.full((Bp, Pp, Mp), 2.0, jnp.float32)
    drawp = drawp.at[:B, :P, :M].set(draw.astype(jnp.float32))
    tpar = jnp.zeros((SUBLANES, Pp), jnp.float32)
    tpar = tpar.at[0, :P].set(a0).at[1, :P].set(a1)
    win = jnp.zeros((SUBLANES, Cp), jnp.float32)
    win = win.at[:4, :C].set(windows.T.astype(jnp.float32))
    cnt, area, wcx, wcy, wc2, ext = cell_rasterize_batch(
        *strips, drawp, tpar, win, n_pairs=P, min_visible=min_visible,
        n_moment=n_moment, block_b=block_b, interpret=interpret)
    return (cnt[:B, :P, :C], area[:B, :P, :C], wcx[:B, :C], wcy[:B, :C],
            wc2[:B, :C], ext[:B, :C])
