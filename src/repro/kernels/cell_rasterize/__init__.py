from repro.kernels.cell_rasterize import ops, ref
from repro.kernels.cell_rasterize.ops import cell_rasterize, window_arrays
