"""Pallas kernel: fleet-batched boxes -> cells x zooms rasterization.

The scene-backed observation provider turns [F, M] object boxes into
[F, N*Z] per-orientation aggregates EVERY controller timestep — the hot
boxes->cells aggregation of the device-resident scene substrate. The
kernel fuses the whole fleet batch: grid = (B / block_b,); each step
loads (block_b, Mp) object strips + (block_b, Pp, Mp) detection draws
plus the static (rows, Cp) window/threshold tables and emits the
(block_b, Pp, Cp) count/area planes and (block_b, Cp) geometry moments.

ops.py pads M and C to 128 lanes and P to the f32 sublane tile (8);
padded objects carry ow = oh = 0 (never visible) and padded pairs carry
draw = 2.0 (never detect), so they contribute nothing. Per grid step the
dominant working set is the [block_b, Mp, Cp] visibility intermediates:
~0.5 MB f32 per array at block_b = 8, Mp = Cp = 128 — an order of
magnitude under VMEM even with the per-pair detection planes live.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(n_pairs: int, min_visible: float, n_moment: int):
    def kernel(ox_ref, oy_ref, ow_ref, oh_ref, draw_ref, tpar_ref, win_ref,
               cnt_ref, area_ref, wcx_ref, wcy_ref, wc2_ref, ext_ref):
        ox = ox_ref[...].astype(jnp.float32)         # [bb, Mp]
        oy = oy_ref[...].astype(jnp.float32)
        ow = ow_ref[...].astype(jnp.float32)
        oh = oh_ref[...].astype(jnp.float32)
        win = win_ref[...].astype(jnp.float32)       # [8, Cp] rows
        x0 = win[0][None, None, :]
        y0 = win[1][None, None, :]
        fw = jnp.maximum(win[2], 1e-6)[None, None, :]
        fh = jnp.maximum(win[3], 1e-6)[None, None, :]

        ox0 = (ox - ow * 0.5)[..., None]             # [bb, Mp, 1]
        ox1 = (ox + ow * 0.5)[..., None]
        oy0 = (oy - oh * 0.5)[..., None]
        oy1 = (oy + oh * 0.5)[..., None]
        ix0 = jnp.maximum(ox0, x0)
        ix1 = jnp.minimum(ox1, x0 + win[2][None, None, :])
        iy0 = jnp.maximum(oy0, y0)
        iy1 = jnp.minimum(oy1, y0 + win[3][None, None, :])
        iw = jnp.maximum(ix1 - ix0, 0.0)             # [bb, Mp, Cp]
        ih = jnp.maximum(iy1 - iy0, 0.0)
        vis = iw * ih / jnp.maximum((ow * oh)[..., None], 1e-9)
        visible = vis >= min_visible
        nw = iw / fw
        nh = ih / fh
        apparent = jnp.maximum(nw, nh)
        a_norm = nw * nh
        ccx = (ix0 + ix1) * 0.5
        ccy = (iy0 + iy1) * 0.5

        tpar = tpar_ref[...].astype(jnp.float32)     # [8, Pp] rows a0, a1
        draw = draw_ref[...].astype(jnp.float32)     # [bb, Pp, Mp]
        n_pad = draw.shape[1]
        mult = jnp.zeros_like(apparent)
        zero_plane = jnp.zeros(apparent.shape[:1] + apparent.shape[2:],
                               jnp.float32)          # [bb, Cp]
        cnts, areas = [], []
        for p in range(n_pad):
            if p >= n_pairs:
                cnts.append(zero_plane)
                areas.append(zero_plane)
                continue
            inv = 1.0 / jnp.maximum(tpar[1, p] - tpar[0, p], 1e-6)
            resp = jnp.clip((apparent - tpar[0, p]) * inv, 0.0, 1.0)
            det = ((draw[:, p, :, None] < resp) & visible).astype(
                jnp.float32)                         # [bb, Mp, Cp]
            cnts.append(jnp.sum(det, axis=1))
            areas.append(jnp.sum(det * a_norm, axis=1))
            if p < n_moment:
                mult = mult + det
        cnt_ref[...] = jnp.stack(cnts, axis=1)
        area_ref[...] = jnp.stack(areas, axis=1)

        wcx_ref[...] = jnp.sum(mult * ccx, axis=1)
        wcy_ref[...] = jnp.sum(mult * ccy, axis=1)
        wc2_ref[...] = jnp.sum(mult * (ccx * ccx + ccy * ccy), axis=1)
        side = jnp.maximum(iw, ih)
        ext_ref[...] = jnp.max(jnp.where(mult > 0, side, 0.0), axis=1)

    return kernel


def cell_rasterize_batch(ox, oy, ow, oh, draw, tpar, win, *,
                         n_pairs: int, min_visible: float = 0.25,
                         n_moment: int | None = None, block_b: int = 8,
                         interpret: bool = True):
    """ox/oy/ow/oh [B, Mp]; draw [B, Pp, Mp]; tpar [8, Pp] (rows 0/1 =
    a0/a1); win [8, Cp] (rows 0-3 = x0/y0/fw/fh). B must be a multiple of
    block_b and n_pairs <= Pp (ops.py pads); the first `n_moment` pair
    channels (default: all) feed the geometry moments. Returns
    (cnt [B, Pp, Cp], area [B, Pp, Cp], wcx, wcy, wc2, ext [B, Cp])."""
    if n_moment is None:
        n_moment = n_pairs
    B, Mp = ox.shape
    _, Pp, _ = draw.shape
    Cp = win.shape[1]
    grid = (B // block_b,)
    strip = pl.BlockSpec((block_b, Mp), lambda i: (i, 0))
    cube = pl.BlockSpec((block_b, Pp, Mp), lambda i: (i, 0, 0))
    stat_t = pl.BlockSpec(tpar.shape, lambda i: (0, 0))
    stat_w = pl.BlockSpec(win.shape, lambda i: (0, 0))
    plane = pl.BlockSpec((block_b, Pp, Cp), lambda i: (i, 0, 0))
    row = pl.BlockSpec((block_b, Cp), lambda i: (i, 0))
    f32 = jnp.float32
    return pl.pallas_call(
        _make_kernel(n_pairs, min_visible, n_moment),
        grid=grid,
        in_specs=[strip, strip, strip, strip, cube, stat_t, stat_w],
        out_specs=[plane, plane, row, row, row, row],
        out_shape=[
            jax.ShapeDtypeStruct((B, Pp, Cp), f32),
            jax.ShapeDtypeStruct((B, Pp, Cp), f32),
            jax.ShapeDtypeStruct((B, Cp), f32),
            jax.ShapeDtypeStruct((B, Cp), f32),
            jax.ShapeDtypeStruct((B, Cp), f32),
            jax.ShapeDtypeStruct((B, Cp), f32),
        ],
        interpret=interpret,
    )(ox, oy, ow, oh, draw, tpar, win)
