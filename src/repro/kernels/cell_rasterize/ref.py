"""Pure-jnp oracle for the boxes -> cells x zooms rasterization.

One fleet-observation step turns every camera's [M] object boxes into the
per-(cell, zoom) aggregates FleetObs consumes. For each object m and FOV
window c (a flattened cell x zoom orientation):

  * clip the object's extent to the window, compute visibility
    (clipped area / object area, kept at >= min_visible — data/render
    .gt_boxes' rule) and the normalized clipped box (nw, nh);
  * apparent size max(nw, nh) drives per-pair detection through the
    saturating teacher response x = clip((apparent - a0) / (a1 - a0));
    an object is detected by pair p when draw[p, m] < x (draws are
    pre-divided by the teacher's plateau p_max; masked objects carry
    draw = 2.0 which can never fire);
  * detected boxes accumulate counts, normalized areas, and the
    multiplicity-weighted center moments (sum w*cx, sum w*cy,
    sum w*(cx^2+cy^2)) + max clipped side that the zoom controller's
    centroid/spread/extent statistics are built from.
"""
from __future__ import annotations

import jax.numpy as jnp


def cell_rasterize_ref(ox, oy, ow, oh, draw, a0, a1, windows,
                       *, min_visible: float = 0.25,
                       n_moment: int | None = None):
    """ox/oy/ow/oh [B, M] object centers+sizes (scene degrees);
    draw [B, P, M] normalized detection draws (2.0 = never detect);
    a0/a1 [P] apparent-size thresholds; windows [C, 4] rows
    (x0, y0, fw, fh). Only the first `n_moment` pair channels (default:
    all) feed the geometry moments/extent.

    Returns (cnt [B, P, C], area [B, P, C], wcx [B, C], wcy [B, C],
    wc2 [B, C], ext [B, C]) — see module docstring for semantics.
    """
    x0 = windows[:, 0][None, None, :]           # [1, 1, C]
    y0 = windows[:, 1][None, None, :]
    fw = windows[:, 2][None, None, :]
    fh = windows[:, 3][None, None, :]
    ox0 = (ox - ow / 2)[..., None]              # [B, M, 1]
    ox1 = (ox + ow / 2)[..., None]
    oy0 = (oy - oh / 2)[..., None]
    oy1 = (oy + oh / 2)[..., None]

    ix0 = jnp.maximum(ox0, x0)
    ix1 = jnp.minimum(ox1, x0 + fw)
    iy0 = jnp.maximum(oy0, y0)
    iy1 = jnp.minimum(oy1, y0 + fh)
    iw = jnp.maximum(ix1 - ix0, 0.0)            # [B, M, C]
    ih = jnp.maximum(iy1 - iy0, 0.0)
    inter = iw * ih
    area_obj = (ow * oh)[..., None]
    vis = inter / jnp.maximum(area_obj, 1e-9)
    visible = vis >= min_visible

    nw = iw / fw
    nh = ih / fh
    apparent = jnp.maximum(nw, nh)
    a_norm = nw * nh
    ccx = (ix0 + ix1) / 2
    ccy = (iy0 + iy1) / 2

    x = jnp.clip((apparent[:, None] - a0[None, :, None, None])
                 / jnp.maximum((a1 - a0)[None, :, None, None], 1e-6),
                 0.0, 1.0)                      # [B, P, M, C]
    det = (draw[..., None] < x) & visible[:, None]
    detf = det.astype(jnp.float32)
    cnt = jnp.sum(detf, axis=2)                 # [B, P, C]
    area = jnp.sum(detf * a_norm[:, None], axis=2)

    if n_moment is None:
        n_moment = detf.shape[1]
    mult = jnp.sum(detf[:, :n_moment], axis=1)  # [B, M, C]
    wcx = jnp.sum(mult * ccx, axis=1)           # [B, C]
    wcy = jnp.sum(mult * ccy, axis=1)
    wc2 = jnp.sum(mult * (ccx * ccx + ccy * ccy), axis=1)
    side = jnp.maximum(iw, ih)
    ext = jnp.max(jnp.where(mult > 0, side, 0.0), axis=1)
    return cnt, area, wcx, wcy, wc2, ext
