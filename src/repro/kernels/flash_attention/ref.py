"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = False, q_offset: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q [B,H,Sq,D], k/v [B,H,Sk,D] -> [B,H,Sq,D]. Exact masked softmax."""
    D = q.shape[-1]
    Sq, Sk = q.shape[2], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)
