"""jit'd public wrapper: BSHD layout, GQA head-sharing, padding to blocks.

`flash_attention(q, k, v)` takes the model-side layout [B, S, H, D] with
possibly fewer KV heads (GQA), pads sequence/head-dim to kernel block
multiples, dispatches the Pallas kernel, and slices the result back.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "q_offset", "scale", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False, q_offset: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q [B,Sq,Hq,D], k/v [B,Sk,Hkv,D] (Hq % Hkv == 0) -> [B,Sq,Hq,D]."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # BSHD -> BHSD
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1)

    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (Sk - 1).bit_length()))
    qt = _pad_to(qt, 2, bq)
    kt = _pad_to(kt, 2, bk)
    vt = _pad_to(vt, 2, bk)
    # pad head dim to the 128-lane width (zero pads leave logits unchanged)
    qt = _pad_to(qt, 3, 128)
    kt = _pad_to(kt, 3, 128)
    vt = _pad_to(vt, 3, 128)

    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, q_offset=q_offset, scale=scale,
        block_q=bq, block_k=bk, sq_valid=Sq, sk_valid=Sk,
        interpret=interpret)
    return out[:, :, :Sq, :D].transpose(0, 2, 1, 3).astype(q.dtype)
