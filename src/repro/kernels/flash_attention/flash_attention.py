"""Flash attention Pallas TPU kernel — tiled online-softmax.

Target: TPU v5e MXU. Layout [B, H, S, D] with D padded to a multiple of 128
(lane width) by the wrapper in ops.py. Grid = (B*H, num_q_blocks,
num_k_blocks); the k axis is the innermost (sequential) grid dimension, so
running max / denominator / accumulator live in VMEM scratch across k steps
(the canonical TPU flash-attention pattern — "arbitrary" semantics on the
b*h and q axes let Mosaic parallelize them, the k axis is declared
sequential).

VMEM budget per step (block_q=block_k=128, D=128, f32):
  q 64 KiB + k 64 KiB + v 64 KiB + acc 64 KiB + m/l 2*64 KiB ≈ 384 KiB
well under the ~16 MiB/core VMEM of v5e; block shapes are (128, 128)
multiples so every matmul maps onto full MXU tiles.

Causal blocks strictly above the diagonal are skipped with pl.when (no MXU
work issued), recovering the ~2x causal saving.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128  # TPU lane width; scratch second-minor dim

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; accept either
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_offset: int, sq_valid: int,
                  sk_valid: int, block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset        # absolute position of q row 0
    k_start = ki * block_k

    # Causal: skip blocks entirely above the diagonal.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, D]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < sk_valid                          # padded K tail
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_scr[:, 0]                            # [bq]
        l_prev = l_scr[:, 0]
        m_cur = jnp.max(s, axis=-1)                     # [bq]
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)

        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - m_safe), 0.0)  # rescale old state

        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc_scr[...] * alpha[:, None]
        acc = acc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        lsum = l_scr[:, 0]
        denom = jnp.where(lsum > 0, lsum, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = False, q_offset: int = 0,
                         scale: float | None = None, block_q: int = 128,
                         block_k: int = 128, sq_valid: int | None = None,
                         sk_valid: int | None = None,
                         interpret: bool = True) -> jnp.ndarray:
    """q [B,H,Sq,D], k/v [B,H,Sk,D] (same head count; GQA handled by ops.py).

    Sq/Sk must be multiples of block_q/block_k (ops.py pads);
    sq_valid/sk_valid give the pre-padding lengths.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    sq_valid = sq_valid or Sq
    sk_valid = sk_valid or Sk
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nq = Sq // block_q
    nk = Sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        sq_valid=sq_valid, sk_valid=sk_valid, block_q=block_q,
        block_k=block_k, nk=nk)

    grid = (B * H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, qi, ki: (bh // H, bh % H, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, qi, ki: (bh // H, bh % H, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),       # output accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out
