"""Serving launcher — the MadEye camera-fleet loop, end to end.

Runs the full adaptive-orientation pipeline on the procedural scene:
controller plans -> camera sweeps -> approximation proxies score -> top-k
ship -> accuracy vs the oracle baselines. `--fleet N` additionally runs
an N-camera fleet through the unified experiment API
(repro.fleet.run_fleet) with the observation provider picked by
`--provider`:

  tables    host-materialized teacher tables, one shared world (default;
            what plain --fleet always ran)
  scene     device-resident heterogeneous scenes + per-camera network
            traces, observations generated inside the episode scan
  detector  scene + the approximation detector in the loop: candidate
            crops rendered and scored by the network inside the scan

`--telemetry PATH|-` streams each fleet run as JSON-lines telemetry
events (repro.obs.events schema: run_start / steps chunks with
per-camera health / run_end) to a file or stdout, with the in-scan
FleetMetrics enabled on the run so events carry EWMA labels, shortlist
hit-rates, and chosen-rank medians.

`--distill` (detector fleets) turns on in-scan continual distillation
(repro.learn): each camera's approximation heads train against the scene
teachers inside the episode scan.

  PYTHONPATH=src python -m repro.launch.serve --fps 5 --duration 20
  PYTHONPATH=src python -m repro.launch.serve --fleet 4 --provider scene
  PYTHONPATH=src python -m repro.launch.serve --fleet 4 --telemetry -
  PYTHONPATH=src python -m repro.launch.serve --fleet 2 \
      --provider detector --shortlist-k 18 --distill
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core.grid import OrientationGrid
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.fleet.api import DEFAULT_QUERIES
from repro.serving import (
    NetworkTrace,
    detection_tables,
    run_madeye,
    run_scheme,
    workload_acc_table,
)

DEFAULT_WORKLOAD = Workload(tuple(Query(*q) for q in DEFAULT_QUERIES))

PROVIDERS = ("tables", "scene", "detector")


def _fleet_spec(provider: str, n: int, *, n_steps, seed, mbps, rtt_ms,
                grid, workload, budget, substrate, shortlist_k=None,
                distill=None):
    """The FleetRunSpec serve runs for `--fleet n --provider name` —
    scene/detector fleets get per-camera heterogeneity (world seeds,
    densities, speeds, mobile network traces); the tables fleet reuses
    the already-built host substrate. `shortlist_k` (detector provider)
    caps the candidate windows scored per camera-step; `distill`
    (detector provider) turns on in-scan continual distillation
    (repro.learn) of the per-camera approximation heads."""
    from repro.fleet import FleetRunSpec

    if provider == "tables":
        video, tables, acc, trace = substrate
        return FleetRunSpec.from_objects(
            "tables", n_cameras=n, n_steps=None, seed=seed, grid=grid,
            workload=workload, budget=budget, video=video, tables=tables,
            trace=trace, acc_table=acc)
    rng = np.random.default_rng(seed)
    kwargs = dict(
        scene_seeds=np.arange(n),
        person_speed=rng.uniform(0.8, 2.0, n),
        car_speed=rng.uniform(6.0, 14.0, n),
        n_people=rng.integers(4, 15, n), n_cars=rng.integers(2, 9, n))
    if provider == "scene":
        kwargs.update(mbps=np.full(n, mbps), rtt_ms=rtt_ms, net_seed=seed)
    return FleetRunSpec.from_objects(
        provider, n_cameras=n, n_steps=n_steps, seed=seed, grid=grid,
        workload=workload, budget=budget,
        shortlist_k=shortlist_k if provider == "detector" else None,
        distill=distill if provider == "detector" else None,
        **kwargs)


def serve(fps: float, duration: float, *, seed: int = 3,
          mbps: float = 24.0, rtt_ms: float = 20.0,
          rotation_speed: float = 400.0, pipelined: bool = False,
          fleet: int = 0, provider: str = "tables",
          fleet_scene: int = 0, fleet_detector: int = 0,
          shortlist_k: int | None = None, distill: bool = False,
          telemetry: str | None = None,
          grid: OrientationGrid = DEFAULT_GRID,
          workload: Workload = DEFAULT_WORKLOAD):
    from repro.fleet import run_fleet
    from repro.obs import episode_events, write_events

    for name, val in (("--fleet", fleet), ("--fleet-scene", fleet_scene),
                      ("--fleet-detector", fleet_detector)):
        if val < 0:
            raise SystemExit(f"{name} must be >= 0, got {val}")
    if provider not in PROVIDERS:
        raise SystemExit(f"--provider must be one of {PROVIDERS}, "
                         f"got {provider!r}")
    # fold the deprecated aliases into (n_cameras, provider) runs
    runs = [(fleet, provider)] if fleet else []
    for n, name, flag in ((fleet_scene, "scene", "--fleet-scene"),
                          (fleet_detector, "detector", "--fleet-detector")):
        if n:
            print(f"note: {flag} N is deprecated; "
                  f"use --fleet N --provider {name}")
            runs.append((n, name))
    if shortlist_k is not None and not any(p == "detector"
                                           for _, p in runs):
        raise SystemExit(
            "--shortlist-k only applies to a detector fleet "
            "(--fleet N --provider detector); no other provider scores "
            "a per-window model, and dropping the flag silently would "
            "make a shortlist sweep meaningless")
    if distill and not any(p == "detector" for _, p in runs):
        raise SystemExit(
            "--distill only applies to a detector fleet "
            "(--fleet N --provider detector); no other provider carries "
            "a per-camera model to train, and dropping the flag "
            "silently would report frozen results as a learning run")

    t0 = time.time()
    video = build_video(grid, SceneConfig(fps=15, seed=seed), duration)
    tables = detection_tables(video, workload)
    acc = workload_acc_table(video, workload, tables)
    trace = NetworkTrace.fixed(mbps, rtt_ms, video.n_frames)
    budget = BudgetConfig(fps=fps, rotation_speed=rotation_speed,
                          pipelined=pipelined)
    print(f"substrate built in {time.time()-t0:.1f}s "
          f"({video.n_frames} frames x {grid.n_cells} cells)")

    res = run_madeye(video, workload, tables, budget, trace, acc_table=acc)
    print(f"MadEye      : acc={res.accuracy:.3f} shape={res.mean_shape:.1f} "
          f"sent/step={res.frames_sent/len(res.visited):.1f} "
          f"best-explored={res.best_explored_rate:.2f}")

    n_steps = max(1, int(duration * fps))
    for n, name in runs:
        spec = _fleet_spec(name, n, n_steps=n_steps, seed=seed, mbps=mbps,
                           rtt_ms=rtt_ms, grid=grid, workload=workload,
                           budget=budget,
                           substrate=(video, tables, acc, trace),
                           shortlist_k=shortlist_k,
                           distill=distill if name == "detector" else None)
        if telemetry is not None:
            # telemetry events enrich from the in-scan FleetMetrics
            spec = dataclasses.replace(spec, metrics=True)
        r = run_fleet(spec)
        wall = r.timings["build_s"] + r.timings["episode_s"]
        print(f"fleet x{n:<4d} [{name}]: acc={r.accuracy:.3f} "
              f"mean shape {r.mean_shape:.1f}, "
              f"sent/step={sum(r.frames_sent)/(r.n_steps*n):.1f}, "
              f"{r.n_steps} steps in {wall:.2f}s end-to-end incl. jit "
              f"compile ({r.camera_steps_per_s:.0f} steady camera-steps/s)")
        if r.distill_loss is not None:
            upd = [v for v in r.distill_loss if v >= 0]
            print(f"  distill: {len(upd)} update steps, loss "
                  f"{upd[0]:.4f} -> {upd[-1]:.4f}" if upd else
                  "  distill: no update steps (ring never filled)")
        if telemetry is not None:
            n_ev = write_events(episode_events(r), telemetry)
            if telemetry != "-":
                print(f"  telemetry: {n_ev} events -> {telemetry}")

    for scheme in ("one_time_fixed", "best_fixed", "best_dynamic",
                   "panoptes", "tracking", "ucb1"):
        r = run_scheme(video, workload, tables, scheme, budget=budget,
                       acc_table=acc)
        print(f"{scheme:12s}: acc={r.accuracy:.3f}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fps", type=float, default=5.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--mbps", type=float, default=24.0)
    ap.add_argument("--rtt-ms", type=float, default=20.0)
    ap.add_argument("--rotation-speed", type=float, default=400.0)
    ap.add_argument("--pipelined", action="store_true")
    ap.add_argument("--fleet", type=int, default=0,
                    help="also run the unified fleet API "
                         "(repro.fleet.run_fleet) with this many cameras")
    ap.add_argument("--provider", choices=PROVIDERS, default="tables",
                    help="observation provider for --fleet: host tables, "
                         "device-resident scenes, or the detector network "
                         "scoring rendered crops in-scan")
    ap.add_argument("--shortlist-k", type=int, default=None,
                    help="detector provider: candidate windows rendered"
                         " + scored per camera-step (multiple of the "
                         "zoom count; default all = exhaustive)")
    ap.add_argument("--distill", action="store_true",
                    help="detector provider: continually distill each "
                         "camera's approximation heads from the scene "
                         "teachers inside the episode scan "
                         "(repro.learn, paper §3.4 defaults)")
    ap.add_argument("--telemetry", type=str, default=None,
                    metavar="PATH|-",
                    help="stream each fleet run as JSONL telemetry "
                         "events (repro.obs.events schema) to a file "
                         "(append) or stdout (-); enables the in-scan "
                         "FleetMetrics on the run")
    ap.add_argument("--fleet-scene", type=int, default=0,
                    help="[deprecated] alias for "
                         "`--fleet N --provider scene`")
    ap.add_argument("--fleet-detector", type=int, default=0,
                    help="[deprecated] alias for "
                         "`--fleet N --provider detector`")
    args = ap.parse_args()
    serve(args.fps, args.duration, seed=args.seed, mbps=args.mbps,
          rtt_ms=args.rtt_ms, rotation_speed=args.rotation_speed,
          pipelined=args.pipelined, fleet=args.fleet,
          provider=args.provider, fleet_scene=args.fleet_scene,
          fleet_detector=args.fleet_detector,
          shortlist_k=args.shortlist_k, distill=args.distill,
          telemetry=args.telemetry)


if __name__ == "__main__":
    main()
