"""Serving launcher — the MadEye camera-fleet loop, end to end.

Runs the full adaptive-orientation pipeline on the procedural scene:
controller plans -> camera sweeps -> approximation proxies score -> top-k
ship -> accuracy vs the oracle baselines. With --nn the approximation
model is the real detector network (repro/models/detector.py) executed
through the batched InferenceEngine instead of the analytic proxy.

  PYTHONPATH=src python -m repro.launch.serve --fps 5 --duration 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core.grid import OrientationGrid
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.serving import (
    NetworkTrace,
    detection_tables,
    run_madeye,
    run_scheme,
    workload_acc_table,
)

DEFAULT_WORKLOAD = Workload((
    Query("yolov4", "person", "count"),
    Query("ssd", "car", "detect"),
    Query("frcnn", "person", "binary"),
    Query("tiny-yolov4", "person", "agg_count"),
))


def serve(fps: float, duration: float, *, seed: int = 3,
          mbps: float = 24.0, rtt_ms: float = 20.0,
          rotation_speed: float = 400.0, pipelined: bool = False,
          fleet: int = 0, fleet_scene: int = 0, fleet_detector: int = 0,
          grid: OrientationGrid = DEFAULT_GRID,
          workload: Workload = DEFAULT_WORKLOAD):
    if fleet < 0:
        raise SystemExit(f"--fleet must be >= 0, got {fleet}")
    if fleet_scene < 0:
        raise SystemExit(f"--fleet-scene must be >= 0, got {fleet_scene}")
    if fleet_detector < 0:
        raise SystemExit(
            f"--fleet-detector must be >= 0, got {fleet_detector}")
    t0 = time.time()
    video = build_video(grid, SceneConfig(fps=15, seed=seed), duration)
    tables = detection_tables(video, workload)
    acc = workload_acc_table(video, workload, tables)
    trace = NetworkTrace.fixed(mbps, rtt_ms, video.n_frames)
    budget = BudgetConfig(fps=fps, rotation_speed=rotation_speed,
                          pipelined=pipelined)
    print(f"substrate built in {time.time()-t0:.1f}s "
          f"({video.n_frames} frames x {grid.n_cells} cells)")

    res = run_madeye(video, workload, tables, budget, trace, acc_table=acc)
    print(f"MadEye      : acc={res.accuracy:.3f} shape={res.mean_shape:.1f} "
          f"sent/step={res.frames_sent/len(res.visited):.1f} "
          f"best-explored={res.best_explored_rate:.2f}")
    if fleet:
        from repro.serving.engine import run_fleet_controller
        t1 = time.time()
        _, out = run_fleet_controller(video, workload, tables, budget,
                                      trace, n_cameras=fleet, acc_table=acc)
        n_steps = int(out.explored.shape[0])
        wall = time.time() - t1
        shapes = np.asarray(out.n_explored, float)
        print(f"fleet x{fleet:<5d}: {n_steps} steps in {wall:.2f}s "
              f"end-to-end incl. jit compile "
              f"({fleet * n_steps / wall:.0f} camera-steps/s, "
              f"mean shape {shapes.mean():.1f}; "
              f"see benchmarks/bench_fleet_scale.py for steady-state)")
    if fleet_scene:
        # device-resident heterogeneous fleet: every camera gets its own
        # scene seed, a spread of densities/speeds, and its own mobile
        # network trace — observations generated inside the episode scan
        from repro.serving.engine import run_fleet_scene_controller
        f = fleet_scene
        n_steps = max(1, int(duration * fps))
        rng = np.random.default_rng(seed)
        t1 = time.time()
        _, out = run_fleet_scene_controller(
            grid, workload, budget, n_cameras=f, n_steps=n_steps,
            seed=seed, scene_seeds=np.arange(f),
            person_speed=rng.uniform(0.8, 2.0, f),
            car_speed=rng.uniform(6.0, 14.0, f),
            n_people=rng.integers(4, 15, f), n_cars=rng.integers(2, 9, f),
            mbps=np.full(f, mbps), rtt_ms=rtt_ms, net_seed=seed)
        wall = time.time() - t1
        shapes = np.asarray(out.n_explored, float)
        print(f"scene x{f:<5d}: {n_steps} steps in {wall:.2f}s "
              f"end-to-end incl. jit compile, zero host tables "
              f"({f * n_steps / wall:.0f} camera-steps/s, "
              f"mean shape {shapes.mean():.1f}; per-camera scenes+nets)")
    if fleet_detector:
        # the full camera-side pipeline: candidate orientations rendered
        # from the device scene and scored by the distilled detector
        # network inside the episode scan — ranking never reads teacher
        # tables, the oracle only grades the chosen orientation
        from repro.serving.engine import run_fleet_detector_controller
        f = fleet_detector
        n_steps = max(1, int(duration * fps))
        t1 = time.time()
        _, out = run_fleet_detector_controller(
            grid, workload, budget, n_cameras=f, n_steps=n_steps,
            seed=seed, scene_seeds=np.arange(f))
        wall = time.time() - t1
        shapes = np.asarray(out.n_explored, float)
        print(f"detect x{f:<4d}: {n_steps} steps in {wall:.2f}s "
              f"end-to-end incl. jit compile, in-scan render+infer "
              f"({f * n_steps / wall:.0f} camera-steps/s, "
              f"mean shape {shapes.mean():.1f}; distilled-model ranking)")
    for scheme in ("one_time_fixed", "best_fixed", "best_dynamic",
                   "panoptes", "tracking", "ucb1"):
        r = run_scheme(video, workload, tables, scheme, budget=budget,
                       acc_table=acc)
        print(f"{scheme:12s}: acc={r.accuracy:.3f}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fps", type=float, default=5.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--mbps", type=float, default=24.0)
    ap.add_argument("--rtt-ms", type=float, default=20.0)
    ap.add_argument("--rotation-speed", type=float, default=400.0)
    ap.add_argument("--pipelined", action="store_true")
    ap.add_argument("--fleet", type=int, default=0,
                    help="also run the JAX fleet controller (repro.fleet) "
                         "with this many cameras")
    ap.add_argument("--fleet-scene", type=int, default=0,
                    help="also run a heterogeneous fleet on the "
                         "device-resident scene substrate (repro."
                         "scene_jax): per-camera scenes + network traces "
                         "generated inside the episode scan")
    ap.add_argument("--fleet-detector", type=int, default=0,
                    help="also run a fleet with the distilled "
                         "approximation model in the loop: candidate "
                         "orientations rendered from the device scene "
                         "and scored by the detector network inside the "
                         "episode scan")
    args = ap.parse_args()
    serve(args.fps, args.duration, seed=args.seed, mbps=args.mbps,
          rtt_ms=args.rtt_ms, rotation_speed=args.rotation_speed,
          pipelined=args.pipelined, fleet=args.fleet,
          fleet_scene=args.fleet_scene, fleet_detector=args.fleet_detector)


if __name__ == "__main__":
    main()
