import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_FULL_UNROLL"] = "1"

"""Roofline analysis runs: exact per-step FLOP/byte/collective totals.

XLA's cost_analysis counts a loop body ONCE regardless of trip count
(verified with a controlled scan-vs-unroll experiment), so the scanned
production programs under-report. This module lowers *unrolled* programs
at 2-3 reduced depths and linearly extrapolates every metric to the full
depth — exact because per-layer structure and sharding are depth-invariant:

  metric(L)        = a + c * L              (LM / vision / diffusion train)
  metric(S, D, Sg) = a + Sg * (b + c_d*D + c_s*Sg_single)   (samplers)

Writes roofline_analysis.json, consumed by benchmarks/bench_roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.analysis --all \
      --out roofline_analysis.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, shapes_for
from repro.configs.base import DiffusionConfig, LMConfig, VisionConfig
from repro.distributed.sharding import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.configs.base import _REGISTRY

METRICS = ("flops", "bytes_accessed", "collective_total")


def _measure(arch: str, shape_name: str, mesh) -> dict:
    cell = build_cell(arch, shape_name, mesh)
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings).lower(*cell.args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_total": float(sum(coll.values())),
        "collective_bytes": {k: int(v) for k, v in coll.items()},
    }
    if mem is not None and hasattr(mem, "temp_size_in_bytes"):
        out["temp_bytes"] = int(mem.temp_size_in_bytes)
        out["arg_bytes"] = int(mem.argument_size_in_bytes)
    return out


def _register_variant(cfg, **changes):
    """Register a reduced-depth clone so build_cell can find it."""
    new = dataclasses.replace(cfg, **changes)
    _REGISTRY[new.name] = new
    return new


def _lm_variants(cfg: LMConfig):
    d = cfg.first_dense_layers
    l1, l2 = d + 2, d + 4
    v1 = _register_variant(cfg, name=f"{cfg.name}@L{l1}", n_layers=l1)
    v2 = _register_variant(cfg, name=f"{cfg.name}@L{l2}", n_layers=l2)
    return (v1, l1), (v2, l2), cfg.n_layers


def _vision_variants(cfg: VisionConfig):
    if cfg.swin:
        # swin stages are heterogeneous: halve the deep stage for the two
        # measurement points — metric is linear in stage-3 depth
        d1 = tuple(min(x, 2) for x in cfg.depths)
        d2 = cfg.depths
        v1 = _register_variant(cfg, name=f"{cfg.name}@d1", depths=d1)
        return (v1, sum(d1)), (cfg, sum(d2)), sum(cfg.depths)
    l1, l2 = 2, 4
    v1 = _register_variant(cfg, name=f"{cfg.name}@L{l1}", n_layers=l1)
    v2 = _register_variant(cfg, name=f"{cfg.name}@L{l2}", n_layers=l2)
    return (v1, l1), (v2, l2), cfg.n_layers


def analyse_linear(arch: str, shape_name: str, mesh) -> dict:
    """Two-point extrapolation in layer count."""
    cfg = get_config(arch)
    if isinstance(cfg, LMConfig):
        (v1, l1), (v2, l2), L = _lm_variants(cfg)
    elif isinstance(cfg, VisionConfig):
        (v1, l1), (v2, l2), L = _vision_variants(cfg)
    else:
        raise TypeError(cfg)
    m1 = _measure(v1.name, shape_name, mesh)
    m2 = _measure(v2.name, shape_name, mesh)
    out = {}
    for k in METRICS:
        c = (m2[k] - m1[k]) / max(l2 - l1, 1)
        a = m1[k] - c * l1
        out[k] = a + c * L
    out["collective_bytes"] = {
        k: int(m2["collective_bytes"].get(k, 0)
               + (m2["collective_bytes"].get(k, 0)
                  - m1["collective_bytes"].get(k, 0))
               / max(l2 - l1, 1) * (L - l2))
        for k in set(m1["collective_bytes"]) | set(m2["collective_bytes"])}
    out["extrapolated_from"] = [l1, l2]
    out["full_depth"] = L
    return out


def analyse_diffusion(arch: str, shape_name: str, mesh) -> dict:
    cfg = get_config(arch)
    shape = get_shape(cfg, shape_name)
    if shape.kind == "train":
        # linear in block count; vary double+single (mmdit) or n_layers
        if cfg.is_mmdit:
            v1 = _register_variant(cfg, name=f"{cfg.name}@b1",
                                   n_double_blocks=2, n_single_blocks=4)
            v2 = _register_variant(cfg, name=f"{cfg.name}@b2",
                                   n_double_blocks=4, n_single_blocks=8)
            # one scalar "block units": double counts 2x a single (two
            # streams) — measured slope handles it since we scale both
            # proportionally (2x from v1 to v2)
            u1 = 2 * 2 + 4
            u2 = 2 * 4 + 8
            U = 2 * cfg.n_double_blocks + cfg.n_single_blocks
        else:
            v1 = _register_variant(cfg, name=f"{cfg.name}@b1", n_layers=2)
            v2 = _register_variant(cfg, name=f"{cfg.name}@b2", n_layers=4)
            u1, u2, U = 2, 4, cfg.n_layers
        m1 = _measure(v1.name, shape_name, mesh)
        m2 = _measure(v2.name, shape_name, mesh)
        out = {}
        for k in METRICS:
            c = (m2[k] - m1[k]) / (u2 - u1)
            out[k] = m1[k] - c * u1 + c * U
        out["collective_bytes"] = m2["collective_bytes"]
        out["extrapolated_from"] = [u1, u2]
        out["full_depth"] = U
        return out

    # sampler cells: metric = a + steps * step_cost(blocks); step_cost
    # linear in block units. 3 compiles: (b1, s1), (b1, s2), (b2, s1).
    if cfg.is_mmdit:
        b1 = _register_variant(cfg, name=f"{cfg.name}@b1",
                               n_double_blocks=2, n_single_blocks=4)
        b2 = _register_variant(cfg, name=f"{cfg.name}@b2",
                               n_double_blocks=4, n_single_blocks=8)
        u1, u2 = 2 * 2 + 4, 2 * 4 + 8
        U = 2 * cfg.n_double_blocks + cfg.n_single_blocks
    else:
        b1 = _register_variant(cfg, name=f"{cfg.name}@b1", n_layers=2)
        b2 = _register_variant(cfg, name=f"{cfg.name}@b2", n_layers=4)
        u1, u2, U = 2, 4, cfg.n_layers
    s1, s2, S = 2, 4, shape.steps

    from repro.configs.base import ShapeSpec
    from repro.configs import shapes as shapes_mod

    def shape_with_steps(steps):
        return ShapeSpec(shape.name, shape.kind, img_res=shape.img_res,
                         global_batch=shape.global_batch, steps=steps)

    # temporarily register reduced-step shapes by monkey-building cells
    def measure(cfg_v, steps):
        sh = shape_with_steps(steps)
        orig = shapes_mod.DIFFUSION_SHAPES
        try:
            shapes_mod.DIFFUSION_SHAPES = [
                sh if s.name == shape.name else s for s in orig]
            shapes_mod.FAMILY_SHAPES["diffusion"] = \
                shapes_mod.DIFFUSION_SHAPES
            return _measure(cfg_v.name, shape.name, mesh)
        finally:
            shapes_mod.DIFFUSION_SHAPES = orig
            shapes_mod.FAMILY_SHAPES["diffusion"] = orig

    m11 = measure(b1, s1)
    m12 = measure(b1, s2)
    m21 = measure(b2, s1)
    out = {}
    for k in METRICS:
        step_b1 = (m12[k] - m11[k]) / (s2 - s1)     # per-step @ u1 blocks
        a = m11[k] - s1 * step_b1                   # steps-independent part
        dstep_db = ((m21[k] - a) / s1 - step_b1) / (u2 - u1)
        step_full = step_b1 + dstep_db * (U - u1)
        out[k] = a + S * step_full
    out["collective_bytes"] = m12["collective_bytes"]
    out["extrapolated_from"] = [[u1, s1], [u1, s2], [u2, s1]]
    out["full_depth"] = [U, S]
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    t0 = time.time()
    if isinstance(cfg, DiffusionConfig):
        out = analyse_diffusion(arch, shape_name, mesh)
    else:
        out = analyse_linear(arch, shape_name, mesh)
    out.update({
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(mesh.devices.size),
        "analysis_s": round(time.time() - t0, 1),
    })
    print(f"[OK] {arch} x {shape_name}: flops={out['flops']:.3e} "
          f"bytes={out['bytes_accessed']:.3e} "
          f"coll={out['collective_total']:.3e} ({out['analysis_s']}s)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="roofline_analysis.json")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            key = (f"{arch}|{shape.name}|"
                   f"{'multi' if args.multi_pod else 'single'}")
            if key in results and "error" not in results[key]:
                continue
            try:
                results[key] = run_cell(arch, shape.name,
                                        multi_pod=args.multi_pod)
            except Exception as e:  # noqa: BLE001
                results[key] = {"arch": arch, "shape": shape.name,
                                "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {arch} x {shape.name}: {e}")
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for v in results.values() if "error" not in v)
    print(f"\n{n_ok}/{len(results)} analysed")


if __name__ == "__main__":
    main()
