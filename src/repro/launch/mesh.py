"""Production meshes.

Single pod  = 16 x 16 = 256 chips  (axes: data, model)
Multi-pod   = 2 x 16 x 16 = 512 chips (axes: pod, data, model)

`pod` is the slow (DCN/inter-pod ICI) axis — pure data parallelism with
optional gradient compression (train/compression.py). `data` carries DP +
FSDP weight sharding; `model` carries TP / EP / SP.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_abstract_mesh(shape: tuple, axes: tuple):
    """Device-free mesh for sharding-rule tests / dry runs.

    jax < 0.5 spells it AbstractMesh(((name, size), ...)); newer releases
    take (sizes, names) positionally — accept both so the sharding tests
    run on every toolchain in the support window.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for unit tests (uses however many devices exist)."""
    devices = jax.devices()[: n_data * n_model]
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         devices=devices)
