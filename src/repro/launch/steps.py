"""Per-(architecture x input-shape) step builders for the dry-run and the
real launchers.

`build_cell(arch, shape_name, mesh)` returns a CellSpec:
  fn            — the pure function to jit (train_step / prefill / decode /
                  forward / sampler)
  args          — ShapeDtypeStruct stand-ins for every input (no alloc)
  in_shardings / out_shardings — NamedSharding pytrees

Conventions per family:
  LM     train_*   -> full train step (fwd+bwd+optimizer update)
         prefill_* -> last-token logits + filled KV cache
         decode_*  -> one-token serve step against a seq_len cache
         long_500k -> decode with a sequence-sharded (SP) cache
  vision train shapes -> train step; serve_* -> jit'd forward
  diff   train_*   -> train step; gen_* -> full sampler loop (steps fwds)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.configs.base import (
    DiffusionConfig,
    LMConfig,
    ShapeSpec,
    VisionConfig,
)
from repro.distributed import sharding as shd
from repro.models import diffusion as diff
from repro.models import kvcache as kvc
from repro.models import swin as swin_mod
from repro.models import vit as vit_mod
from repro.models.mmdit import TXT_TOKENS
from repro.train import trainer

KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)

# giant-MoE training uses Adafactor (factored second moment); dense fits
# AdamW comfortably
_ADAFACTOR_ARCHS = {"kimi-k2-1t-a32b", "deepseek-v3-671b"}


@dataclass
class CellSpec:
    arch: str
    shape: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    static_kwargs: dict


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def _param_and_opt_shapes(ts: trainer.TrainStep):
    key = KEY_SDS
    p_shape = jax.eval_shape(ts.init_params, key)
    o_shape = jax.eval_shape(ts.init_opt, p_shape)
    return p_shape, o_shape


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(cfg: LMConfig, shape: ShapeSpec, mesh: Mesh) -> CellSpec:
    moe = bool(cfg.moe_experts)
    if shape.kind == "train":
        opt_name = ("adafactor" if cfg.name in _ADAFACTOR_ARCHS else "adamw")
        ts = trainer.make_train_step(cfg, optimizer=opt_name)
        p_shape, o_shape = _param_and_opt_shapes(ts)
        batch = ts.batch_spec(shape)
        p_sh = shd.param_shardings(p_shape, mesh)
        o_sh = shd.opt_shardings(o_shape, mesh)
        b_sh = shd.batch_shardings(batch, mesh)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())}
        return CellSpec(
            cfg.name, shape.name, ts.step,
            (p_shape, o_shape, batch, KEY_SDS),
            (p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
            (p_sh, o_sh, metrics_sh), {})

    if shape.kind == "prefill":
        if moe:
            fn = (kvc.mla_prefill if cfg.mla else kvc.moe_gqa_prefill)
        else:
            fn = kvc.gqa_prefill
        from repro.models import moe_lm, transformer
        init = (moe_lm.moe_lm_init if moe else transformer.lm_init)
        p_shape = jax.eval_shape(lambda k: init(k, cfg), KEY_SDS)
        tokens = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)
        def step(params, tokens, _fn=fn):
            return _fn(params, cfg, tokens, max_seq=shape.seq_len,
                       last_only=True)
        out_shape = jax.eval_shape(step, p_shape, tokens)
        p_sh = shd.param_shardings(p_shape, mesh)
        t_sh = shd.batch_shardings({"t": tokens}, mesh)["t"]
        cache_sh = shd.kvcache_shardings(out_shape[1], mesh)
        logits_sh = jax.tree.map(
            lambda _: NamedSharding(
                mesh, P(shd.dp_axes(mesh), None, "model")), out_shape[0])
        return CellSpec(cfg.name, shape.name, step, (p_shape, tokens),
                        (p_sh, t_sh), (logits_sh, cache_sh), {})

    # decode cells (decode_32k, long_500k). REPRO_SP_THRESHOLD lowers the
    # sequence-parallel cutoff (§Perf: SP also pays off at 32k decode once
    # kv-heads don't divide the TP axis).
    import os
    sp_threshold = int(os.environ.get("REPRO_SP_THRESHOLD", "262144"))
    seq_parallel = shape.seq_len >= sp_threshold
    if moe:
        step_fn = (kvc.mla_decode_step if cfg.mla else kvc.moe_gqa_decode_step)
    else:
        step_fn = kvc.gqa_decode_step
    from repro.models import moe_lm, transformer
    init = (moe_lm.moe_lm_init if moe else transformer.lm_init)
    p_shape = jax.eval_shape(lambda k: init(k, cfg), KEY_SDS)
    B = shape.global_batch
    cache = kvc.cache_specs(cfg, B, shape.seq_len)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def step(params, tok, cache, _fn=step_fn):
        return _fn(params, cfg, tok, cache)
    p_sh = shd.param_shardings(p_shape, mesh)
    tok_sh = shd.batch_shardings({"t": token}, mesh)["t"]
    cache_sh = shd.kvcache_shardings(cache, mesh,
                                     sequence_parallel=seq_parallel)
    logits_sh = NamedSharding(
        mesh, P(shd.dp_axes(mesh) if B > 1 else None, None, "model"))
    return CellSpec(cfg.name, shape.name, step, (p_shape, token, cache),
                    (p_sh, tok_sh, cache_sh), (logits_sh, cache_sh), {})


# ---------------------------------------------------------------------------
# Vision cells
# ---------------------------------------------------------------------------

def _vision_cell(cfg: VisionConfig, shape: ShapeSpec, mesh: Mesh) -> CellSpec:
    if shape.kind == "train":
        # cls_384 fine-tunes at higher res — rebuild specs at that res
        ts = trainer.make_train_step(cfg)
        p_shape, o_shape = _param_and_opt_shapes(ts)
        batch = ts.batch_spec(shape)
        p_sh = shd.param_shardings(p_shape, mesh)
        o_sh = shd.opt_shardings(o_shape, mesh)
        b_sh = shd.batch_shardings(batch, mesh)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())}
        return CellSpec(cfg.name, shape.name, ts.step,
                        (p_shape, o_shape, batch, KEY_SDS),
                        (p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                        (p_sh, o_sh, metrics_sh), {})

    fwd = (swin_mod.swin_forward if cfg.swin else vit_mod.vit_forward)
    init = (swin_mod.swin_init if cfg.swin else vit_mod.vit_init)
    p_shape = jax.eval_shape(lambda k: init(k, cfg), KEY_SDS)
    images = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.img_res, shape.img_res, 3), jnp.float32)

    def step(params, x):
        return fwd(params, cfg, x)
    p_sh = shd.param_shardings(p_shape, mesh)
    i_sh = shd.batch_shardings({"x": images}, mesh)["x"]
    out_sh = NamedSharding(
        mesh, P(shd.dp_axes(mesh) if shape.global_batch > 1 else None, None))
    return CellSpec(cfg.name, shape.name, step, (p_shape, images),
                    (p_sh, i_sh), out_sh, {})


# ---------------------------------------------------------------------------
# Diffusion cells
# ---------------------------------------------------------------------------

def _diffusion_cell(cfg: DiffusionConfig, shape: ShapeSpec,
                    mesh: Mesh) -> CellSpec:
    if shape.kind == "train":
        ts = trainer.make_train_step(cfg)
        p_shape, o_shape = _param_and_opt_shapes(ts)
        batch = ts.batch_spec(shape)
        p_sh = shd.param_shardings(p_shape, mesh)
        o_sh = shd.opt_shardings(o_shape, mesh)
        b_sh = shd.batch_shardings(batch, mesh)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())}
        return CellSpec(cfg.name, shape.name, ts.step,
                        (p_shape, o_shape, batch, KEY_SDS),
                        (p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                        (p_sh, o_sh, metrics_sh), {})

    # generation cells: full sampler loop, `steps` backbone forwards
    B = shape.global_batch
    lat_res = (cfg.latent_res or cfg.img_res // 8)
    if cfg.latent_res and shape.img_res:
        lat_res = cfg.latent_res * shape.img_res // cfg.img_res
    elif shape.img_res:
        lat_res = shape.img_res // 8
    from repro.models import dit as dit_mod
    from repro.models import mmdit as mmdit_mod
    if cfg.is_mmdit:
        p_shape = jax.eval_shape(lambda k: mmdit_mod.mmdit_init(k, cfg),
                                 KEY_SDS)
        txt = jax.ShapeDtypeStruct((B, TXT_TOKENS, cfg.cond_dim),
                                   jnp.float32)

        def step(params, key, txt_emb):
            return diff.rf_sample(params, cfg, key, batch=B,
                                  n_steps=shape.steps, txt_emb=txt_emb,
                                  latent_res=lat_res)

        args = (p_shape, KEY_SDS, txt)
        extra_sh = (shd.batch_shardings({"t": txt}, mesh)["t"],)
    else:
        p_shape = jax.eval_shape(lambda k: dit_mod.dit_init(k, cfg),
                                 KEY_SDS)
        y = jax.ShapeDtypeStruct((B,), jnp.int32)

        def step(params, key, labels):
            return diff.dit_sample(params, cfg, key, batch=B,
                                   n_steps=shape.steps, y=labels,
                                   latent_res=lat_res)

        args = (p_shape, KEY_SDS, y)
        extra_sh = (shd.batch_shardings({"t": y}, mesh)["t"],)
    p_sh = shd.param_shardings(p_shape, mesh)
    dp = shd.dp_axes(mesh)
    b_axis = dp if B % shd.axis_size(mesh, dp) == 0 else None
    out_sh = NamedSharding(mesh, P(b_axis, None, None, None))
    return CellSpec(cfg.name, shape.name, step, args,
                    (p_sh, NamedSharding(mesh, P())) + extra_sh,
                    out_sh, {})


# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh) -> CellSpec:
    cfg = get_config(arch)
    shape = get_shape(cfg, shape_name)
    if cfg.family == "lm":
        return _lm_cell(cfg, shape, mesh)
    if cfg.family == "vision":
        return _vision_cell(cfg, shape, mesh)
    if cfg.family == "diffusion":
        return _diffusion_cell(cfg, shape, mesh)
    raise ValueError(cfg.family)
