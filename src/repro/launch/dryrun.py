import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory/cost/collective analyses.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, an unpartitionable op, or an absurd
memory footprint all surface here as compile failures or pathological
analysis numbers.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch vit-b16 --shape cls_224
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, shapes_for
from repro.distributed.sharding import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

# full-attention archs skip long_500k per the pool note (quadratic prefill
# is out of scope; decode is O(S) and IS lowered — see DESIGN.md §4).
# We run long_500k for every LM arch because decode against a 500k cache
# is linear per step; nothing to skip.
SKIPPED_CELLS: set = set()


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    n_chips = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(n_chips),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "collective_total": int(sum(coll.values())),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            result[attr] = int(getattr(mem, attr))
    if mem is not None and hasattr(mem, "temp_size_in_bytes"):
        per_dev = (result.get("argument_size_in_bytes", 0)
                   + result.get("temp_size_in_bytes", 0)) / n_chips
        result["bytes_per_device"] = int(per_dev)
    if verbose:
        print(f"[OK] {arch} x {shape_name} ({result['mesh']}): "
              f"flops={result['flops']:.3e} "
              f"coll={result['collective_total']:.3e}B "
              f"mem/dev={result.get('bytes_per_device', 0)/2**30:.2f}GiB "
              f"compile={t_compile:.0f}s")
    return result


def run_all(archs=None, shapes=None, *, multi_pod: bool = False,
            out_path: str | None = None, resume: dict | None = None):
    results = dict(resume or {})
    archs = archs or ASSIGNED_ARCHS
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if shapes and shape.name not in shapes:
                continue
            key = f"{arch}|{shape.name}|{'multi' if multi_pod else 'single'}"
            if key in results and "error" not in results[key]:
                continue
            try:
                results[key] = run_cell(arch, shape.name,
                                        multi_pod=multi_pod)
            except Exception as e:  # noqa: BLE001 — record and continue
                results[key] = {"arch": arch, "shape": shape.name,
                                "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {arch} x {shape.name}: {e}")
                traceback.print_exc()
            if out_path:
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        resume = None
        if args.out and os.path.exists(args.out):
            with open(args.out) as f:
                resume = json.load(f)
        shapes = [args.shape] if args.shape else None
        res = run_all(archs=[args.arch] if args.arch else None,
                      shapes=shapes, multi_pod=args.multi_pod,
                      out_path=args.out, resume=resume)
        if args.both_meshes:
            res = run_all(archs=[args.arch] if args.arch else None,
                          shapes=shapes, multi_pod=True,
                          out_path=args.out, resume=res)
        n_ok = sum(1 for v in res.values() if "error" not in v)
        print(f"\n{n_ok}/{len(res)} cells OK")
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        r = run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        print(json.dumps(r, indent=2))


if __name__ == "__main__":
    main()
