"""Distributed training launcher.

Real-cluster entry point: builds the mesh, shards params/optimizer with
distributed/sharding.py, restores the latest checkpoint if present, and
runs the fault-tolerant train loop (heartbeats + stragglers + atomic
checkpoints). On this CPU container it runs the smoke configs end-to-end
(--smoke) — the full configs are exercised via dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch vit-b16 --smoke \
      --steps 20 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.train import checkpoint as ckpt
from repro.train import trainer
from repro.train.fault import HeartbeatTable, RestartPolicy, deadline_for_step


def synthetic_batch(cfg, shape: ShapeSpec, key):
    """Learnable synthetic batch matching trainer.batch_specs.

    LM tokens follow an affine recurrence (t[i+1] = (a*t[i] + c) mod V)
    with labels = next token, so the loss has real signal to descend
    (uniform-random tokens would floor at ln(V))."""
    specs = trainer.batch_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        k = jax.random.fold_in(key, abs(hash(name)) % (2 ** 31))
        if name == "tokens":
            V = cfg.vocab
            start = jax.random.randint(k, sds.shape[:-1] + (1,), 0, V)
            steps = jnp.arange(sds.shape[-1])
            # t_i = (start + 7*i) mod V — perfectly predictable sequence
            out[name] = (start + 7 * steps) % V
        elif name == "labels" and "tokens" in specs:
            out[name] = None      # filled below from tokens
        elif sds.dtype == jnp.int32:
            hi = getattr(cfg, "vocab", getattr(cfg, "n_classes", 2))
            out[name] = jax.random.randint(k, sds.shape, 0, hi)
        elif sds.dtype == jnp.bool_:
            out[name] = jnp.ones(sds.shape, bool)
        else:
            out[name] = jax.random.normal(k, sds.shape, sds.dtype) * 0.1
    if out.get("labels", 0) is None:
        out["labels"] = jnp.roll(out["tokens"], -1, axis=-1)
    return out


def train_loop(cfg, shape: ShapeSpec, *, steps: int, lr: float,
               ckpt_dir: str | None, ckpt_every: int = 50,
               log_every: int = 5):
    ts = trainer.make_train_step(cfg, lr=lr)
    key = jax.random.PRNGKey(0)
    params = ts.init_params(key)
    opt = ts.init_opt(params)
    start = 0
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (params, opt), manifest = ckpt.restore(
                ckpt_dir, last, (params, opt))
            start = manifest["step"]
            print(f"restored checkpoint step {start}")

    step_fn = jax.jit(ts.step)
    hb = HeartbeatTable(n_hosts=jax.process_count())
    policy = RestartPolicy()
    history = []

    for step in range(start, steps):
        t0 = time.time()
        batch = synthetic_batch(cfg, shape, jax.random.fold_in(key, step))
        params, opt, metrics = step_fn(params, opt, batch,
                                       jax.random.fold_in(key, 10 ** 6 + step))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        history.append(dt)
        hb.beat(jax.process_index(), dt)

        if step % log_every == 0:
            ddl = deadline_for_step(history[:-1])
            flag = " [STRAGGLER]" if dt > ddl and len(history) > 10 else ""
            print(f"step {step:5d} loss {loss:.4f} "
                  f"grad {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                  f"{flag}")
        if ckpt_dir and step and step % ckpt_every == 0:
            path = ckpt.save(ckpt_dir, step, (params, opt))
            ckpt.prune_old(ckpt_dir)
            print(f"checkpointed -> {path}")

        dead = hb.dead_hosts()
        if dead:
            action = policy.decide(len(dead), hb.n_hosts, model_parallel=1)
            print(f"dead hosts {dead} -> {action}")

    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, (params, opt))
    return params, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "lm":
        shape = ShapeSpec("cli", "train", seq_len=args.seq,
                          global_batch=args.batch)
    elif cfg.family == "vision":
        shape = ShapeSpec("cli", "train", img_res=cfg.img_res,
                          global_batch=args.batch)
    else:
        shape = ShapeSpec("cli", "train", img_res=cfg.img_res,
                          global_batch=args.batch)
    train_loop(cfg, shape, steps=args.steps, lr=args.lr,
               ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
