"""The in-scan learning loop: (trainable params, opt state, ring) riding
the jit'd episode carry.

`LearnState` threads through runner._episode's lax.scan inside the
DetectorProvider carry; `distill_step` is the optimizer step that runs
ENTIRELY inside the scan (no per-step host transfers) on the cadence
DistillSpec.every sets. The design constraints, in order:

  * one update-rule definition — `optimizer_apply` is the single place
    an optimizer touches params; the host-side `core/continual
    .finetune_step` delegates to `finetune_update` here, so the offline
    and in-scan paths cannot drift;
  * per-camera independence — the loss vmaps per camera, gradient
    clipping is per-camera (train/optim.adamw_update's built-in clip is
    a GLOBAL norm across all leaves, which would couple cameras through
    the fleet axis — so it is disabled and reapplied per row), and
    cameras whose ring is empty are a bit-exact no-op (a `where` on
    params AND moments: AdamW's weight decay would otherwise drift idle
    cameras' heads);
  * frozen-backbone exactness — head-only mode trains per-camera head
    convs on features the shared frozen backbone staged during the
    inference forward, so the staged features are exactly what a fresh
    backbone pass would produce and training adds only head-conv
    FLOPs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.learn.loss import distill_full_loss, distill_head_loss
from repro.learn.pairs import PairBuffer, init_pair_buffer
from repro.learn.spec import DistillSpec
from repro.models import detector as det
from repro.train import optim


class LearnState(NamedTuple):
    """Device pytree riding the episode scan carry (distill on only).

    params: the trainable subtree with a leading fleet axis [F, ...] —
    the heads dict in head-only mode, the full detector pytree
    otherwise. staged/staged_widx hold the current step's inference
    payload between the observe and learn hooks of one scan iteration.
    """
    params: Any             # [F, ...] per-camera trainable params
    opt: Any                # AdamState | SGDState over `params`
    buf: PairBuffer
    staged: jnp.ndarray     # [F, K, ...] this step's student payload
    staged_widx: jnp.ndarray  # [F, K] int32 window ids of the payload


def trainable_mask(dspec: DistillSpec, trainable) -> Any:
    """Optimizer mask over the trainable pytree. Head-only: everything
    (the subtree IS the heads). Full: everything except the shared patch
    embedding — the staged tokens were produced by it, so its grads are
    structurally zero and Adam/decay must not drift it."""
    if dspec.head_only:
        return jax.tree.map(lambda _: True, trainable)
    m = jax.tree.map(lambda _: True, trainable)
    m["backbone"]["vit"]["patch_embed"] = jax.tree.map(
        lambda _: False, trainable["backbone"]["vit"]["patch_embed"])
    return m


def init_learn(dspec: DistillSpec, det_cfg, det_params, n_cameras: int,
               shortlist_k: int) -> LearnState:
    """Broadcast the trainable subtree per camera and size the ring +
    staging buffers. Runs inside jit (init_carry)."""
    f = n_cameras
    g = det_cfg.img_res // det_cfg.patch
    sub = det_params["heads"] if dspec.head_only else det_params
    params = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (f,) + p.shape), sub)
    mask = trainable_mask(dspec, params)
    if dspec.optimizer == "adamw":
        opt = optim.adamw_init(params, mask)
    else:
        opt = optim.sgd_init(params)
    if dspec.head_only:
        payload = (g, g, det_cfg.fpn_dim)
    else:
        payload = (g * g, det_cfg.d_model)
    return LearnState(
        params=params, opt=opt,
        buf=init_pair_buffer(f, dspec.buffer, payload, det_cfg.max_boxes,
                             dtype=det_cfg.dtype),
        staged=jnp.zeros((f, shortlist_k) + payload, det_cfg.dtype),
        staged_widx=jnp.zeros((f, shortlist_k), jnp.int32))


def lr_at(dspec: DistillSpec, step) -> jnp.ndarray:
    if dspec.schedule == "constant":
        return jnp.asarray(dspec.lr, jnp.float32)
    return optim.cosine_schedule(dspec.lr, dspec.warmup,
                                 dspec.horizon)(step)


def optimizer_apply(name: str, params, grads, opt_state, *, lr,
                    mask=None, weight_decay: float = 0.0,
                    grad_clip: float | None = None):
    """THE optimizer update — every training path in the repo (in-scan
    distillation here, host-side continual fine-tuning through
    `finetune_update`) funnels into this one call, so there is exactly
    one update rule to audit. Returns (params', opt_state')."""
    if name == "adamw":
        return optim.adamw_update(params, grads, opt_state, lr=lr,
                                  mask=mask, weight_decay=weight_decay,
                                  grad_clip=grad_clip)
    if name == "sgd":
        return optim.sgd_update(params, grads, opt_state, lr=lr)
    raise ValueError(f"unknown optimizer {name!r} (adamw | sgd)")


def _per_camera_clip(grads, mask, clip: float) -> Any:
    """Per-camera global-norm clip over the trainable leaves: each
    camera's row scales by its OWN norm, so no gradient information
    crosses the fleet axis (the fleet-size-independence invariant)."""
    sq = None
    for g, keep in zip(jax.tree.leaves(grads), jax.tree.leaves(mask)):
        if not keep:
            continue
        s = jnp.sum(jnp.square(g.astype(jnp.float32)),
                    axis=tuple(range(1, g.ndim)))
        sq = s if sq is None else sq + s
    gnorm = jnp.sqrt(sq)                                    # [F]
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))

    def app(g):
        return g * scale.reshape((g.shape[0],)
                                 + (1,) * (g.ndim - 1)).astype(g.dtype)

    return jax.tree.map(app, grads)


def distill_update(dspec: DistillSpec, det_cfg, lc: LearnState
                   ) -> tuple[LearnState, jnp.ndarray]:
    """One optimizer step over every camera's ring. Returns (new state,
    per-camera loss [F] — -1.0 for cameras whose ring was empty and
    whose params/moments pass through bit-unchanged)."""
    buf = lc.buf
    f = buf.weight.shape[0]

    if dspec.head_only:
        def cam_loss(tr, x, bx, cl, vl, w):
            return distill_head_loss(tr, x, bx, cl, vl, w)
    else:
        def cam_loss(tr, x, bx, cl, vl, w):
            return distill_full_loss(tr, det_cfg, x, bx, cl, vl, w)

    def total(params):
        losses = jax.vmap(cam_loss)(params, buf.x, buf.boxes,
                                    buf.classes, buf.valid, buf.weight)
        return jnp.sum(losses), losses

    (_, losses), grads = jax.value_and_grad(total, has_aux=True)(lc.params)
    mask = trainable_mask(dspec, lc.params)
    if dspec.grad_clip is not None:
        grads = _per_camera_clip(grads, mask, dspec.grad_clip)
    lr_t = lr_at(dspec, lc.opt.step)
    new_params, new_opt = optimizer_apply(
        dspec.optimizer, lc.params, grads, lc.opt, lr=lr_t, mask=mask,
        weight_decay=dspec.weight_decay, grad_clip=None)

    # idle cameras (empty ring) are a bit-exact no-op: weight decay and
    # Adam moments must not drift params that saw no data
    has = buf.weight.sum(axis=-1) > 0                       # [F]

    def keep_new(keep, n, o):
        if not keep:
            return n                    # masked leaves never changed
        return jnp.where(has.reshape((f,) + (1,) * (n.ndim - 1)), n, o)

    new_params = jax.tree.map(keep_new, mask, new_params, lc.params)
    if dspec.optimizer == "adamw":
        new_opt = optim.AdamState(
            new_opt.step,
            jax.tree.map(keep_new, mask, new_opt.mu, lc.opt.mu),
            jax.tree.map(keep_new, mask, new_opt.nu, lc.opt.nu))
    else:
        new_opt = optim.SGDState(
            new_opt.step,
            jax.tree.map(keep_new, mask, new_opt.momentum,
                         lc.opt.momentum))
    loss_out = jnp.where(has, losses, -1.0)
    return lc._replace(params=new_params, opt=new_opt), loss_out


def distill_step(dspec: DistillSpec, det_cfg, lc: LearnState, step_idx
                 ) -> tuple[LearnState, dict]:
    """The cadence-gated update (lax.cond keeps off-steps free). step_idx
    is the post-step controller step count ([F], all equal — steps are
    1-based after fleet_step increments). Returns (state', aux) with aux
    {"loss": [F] (-1.0 on skipped/idle), "lr": [F]}."""
    f = lc.buf.weight.shape[0]
    do = (step_idx[0] % dspec.every) == 0
    lc, loss = jax.lax.cond(
        do,
        lambda s: distill_update(dspec, det_cfg, s),
        lambda s: (s, jnp.full((f,), -1.0)),
        lc)
    lr_t = lr_at(dspec, lc.opt.step)
    return lc, {"loss": loss, "lr": jnp.broadcast_to(lr_t, (f,))}


def merged_params(dspec: DistillSpec, det_params, trained, camera=None):
    """Recombine the per-camera trained subtree with the shared frozen
    rest into full detector params. camera=None keeps the leading fleet
    axis on the trained leaves (head-only mode then mixes shared
    backbone + [F, ...] heads — slice before saving); an int selects one
    camera's checkpoint, ready for `save_detector_params`."""
    take = (lambda p: p) if camera is None else (lambda p: p[camera])
    trained = jax.tree.map(take, trained)
    if dspec.head_only:
        return {"backbone": det_params["backbone"], "heads": trained}
    return trained


# ---------------------------------------------------------------------------
# host-side fine-tune (core/continual.py delegates here)
# ---------------------------------------------------------------------------

def finetune_update(params, opt_state, cfg, images, gt_boxes, gt_classes,
                    gt_valid, *, lr: float = 1e-3,
                    weight_decay: float = 1e-4):
    """One offline continual-learning gradient step — the exact update
    `core/continual.finetune_step` always ran (frozen backbone,
    heads-only AdamW, global grad clip), now expressed through the same
    `optimizer_apply` the in-scan loop uses. Returns (params', state',
    loss)."""
    def loss_fn(p):
        return det.detector_loss(p, cfg, images, gt_boxes, gt_classes,
                                 gt_valid, freeze_backbone=True)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    mask = det.head_params_mask(params)
    params, opt_state = optimizer_apply(
        "adamw", params, grads, opt_state, lr=lr, mask=mask,
        weight_decay=weight_decay, grad_clip=1.0)
    return params, opt_state, loss


def init_finetune_state(params):
    """Optimizer state sized to the heads only (97% state savings)."""
    return optim.adamw_init(params, det.head_params_mask(params))
