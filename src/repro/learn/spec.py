"""DistillSpec — the declarative, JSON-round-trippable description of
one in-scan continual-distillation configuration.

Hung off `FleetRunSpec.distill` exactly like `MetricsSpec` hangs off
`.metrics`: frozen + hashable, so it rides the DetectorProvider as
aux_data and keys the jit cache — `distill=None` compiles the *exact*
pre-learning episode program (decisions bit-identical to a frozen-params
run, pinned by tests/test_learn.py), while any enabled spec compiles the
learning variant once.

The fields mirror the paper's knobs (§3.4: head-only fine-tuning with
only camera resources) plus the machinery this repo adds to make the
update ride the scan: how many sent crops to harvest per step, the
per-camera ring-buffer depth, and the update cadence.
"""
from __future__ import annotations

from dataclasses import dataclass

OPTIMIZERS = ("adamw", "sgd")
SCHEDULES = ("constant", "cosine")


@dataclass(frozen=True)
class DistillSpec:
    """Static (hashable, jit-cache-keyed) in-scan distillation config.

    enabled=False is equivalent to passing no spec at all (FleetRunSpec
    normalizes it to None). `head_only=True` is the paper's mode — only
    the final prediction heads train, per camera, on features staged
    from the inference forward (zero extra backbone compute);
    `head_only=False` trains the full network per camera from the staged
    patch tokens (the shared patch embedding stays frozen — it produced
    the tokens).

    harvest: sent crops captured per camera per step (chosen orientation
    first, then best predicted accuracy). buffer: per-camera pair ring
    depth the update trains over. every: optimizer-step cadence in
    controller steps. horizon/warmup parameterize the cosine schedule
    (in optimizer steps); constant ignores them.
    """
    enabled: bool = True
    optimizer: str = "adamw"        # adamw | sgd
    lr: float = 3e-3
    schedule: str = "constant"      # constant | cosine
    warmup: int = 0
    horizon: int = 256
    head_only: bool = True
    every: int = 1
    buffer: int = 8
    harvest: int = 2
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0   # per-camera global-norm clip

    def __post_init__(self):
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(f"DistillSpec.optimizer must be one of "
                             f"{OPTIMIZERS}, got {self.optimizer!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"DistillSpec.schedule must be one of "
                             f"{SCHEDULES}, got {self.schedule!r}")
        for name in ("every", "buffer", "harvest"):
            if getattr(self, name) < 1:
                raise ValueError(
                    f"DistillSpec.{name} must be >= 1, got "
                    f"{getattr(self, name)}")
        if self.harvest > self.buffer:
            raise ValueError(
                f"DistillSpec.harvest={self.harvest} exceeds the "
                f"buffer={self.buffer} ring — later harvests of one step "
                f"would overwrite earlier ones before any update sees "
                f"them")
        if self.lr <= 0:
            raise ValueError(f"DistillSpec.lr must be > 0, got {self.lr}")


def normalize_distill(d) -> DistillSpec | None:
    """The FleetRunSpec normalization rule (mirrors `metrics`):
    True -> default spec, False/None -> None, dict -> DistillSpec(**d),
    enabled=False -> None."""
    if d is True:
        d = DistillSpec()
    elif d is False:
        d = None
    elif isinstance(d, dict):
        d = DistillSpec(**d)
    if d is not None and not d.enabled:
        d = None
    return d
