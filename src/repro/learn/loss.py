"""The distillation objective: pair buffer -> per-camera scalar loss.

One loss definition for the whole repo: both heads reduce to
`models/detector.detector_loss_from_outputs` — the exact math
`detector_loss` (and through it the host-side `core/continual
.finetune_step`) trains with — applied to the static-shape teacher
targets of `core/distill.DistillTargets` layout (boxes cxcywh, classes,
valid), weighted by the ring's slot-fill mask so empty buffer slots
contribute nothing.

Two payload modes, matching DistillSpec.head_only:

  * `distill_head_loss` — payload is staged post-neck features; only the
    per-camera head convs run forward+backward (the paper's "final 3
    prediction layers", and how the <30% in-scan overhead gate is met);
  * `distill_full_loss` — payload is staged patch tokens; the whole
    per-camera network (minus the shared patch embedding that produced
    the tokens) runs forward+backward.

Both take single-camera tensors and are vmapped over the fleet axis by
learn/loop.py, which keeps every camera's gradient independent.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.detector import (
    detector_loss_from_outputs,
    detector_loss_tokens,
    head_outputs,
)


def distill_head_loss(heads, feats: jnp.ndarray, boxes: jnp.ndarray,
                      classes: jnp.ndarray, valid: jnp.ndarray,
                      weight: jnp.ndarray) -> jnp.ndarray:
    """Head-only objective for ONE camera's ring.

    heads: the camera's trainable head params; feats [B, g, g, Fd]
    staged post-neck features; boxes/classes/valid the teacher targets
    ([B, mb, ...]); weight [B] slot-fill weights. Returns a scalar.
    """
    cls_logits, box_raw, obj_logits = head_outputs(heads, feats)
    return detector_loss_from_outputs(cls_logits, box_raw, obj_logits,
                                      boxes, classes, valid, weight=weight)


def distill_full_loss(params, cfg, tokens: jnp.ndarray, boxes: jnp.ndarray,
                      classes: jnp.ndarray, valid: jnp.ndarray,
                      weight: jnp.ndarray) -> jnp.ndarray:
    """Full-param objective for ONE camera's ring: staged patch tokens
    [B, P, D] re-run through the camera's trainable backbone + heads."""
    return detector_loss_tokens(params, cfg, tokens, boxes, classes, valid,
                                weight=weight, freeze_backbone=False)
