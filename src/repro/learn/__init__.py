"""In-scan continual distillation (paper §3.4) — the scan carry learns.

MadEye's second pillar: approximation models are continually distilled
from the registered queries' teachers "with only camera resources". This
package closes that loop *inside* the jit'd fleet episode:

  spec.py   DistillSpec — declarative, JSON-round-trippable config hung
            off FleetRunSpec.distill (optimizer, lr schedule, head-only
            vs full-param, cadence, ring depth); None compiles the exact
            pre-learning program
  pairs.py  training-pair harvesting from the crops the budget actually
            SENT: teacher grades of the chosen/sent windows, student
            payload reused from the existing [F*K] fused forward —
            training cost scales with shortlist_k, not N*Z
  loss.py   the distill objective, reduced to models/detector
            .detector_loss_from_outputs (one loss definition repo-wide)
  loop.py   LearnState riding the scan carry; the cadence-gated
            per-camera optimizer step (train/optim) with per-camera
            clipping and idle-camera no-ops, plus the `finetune_update`
            that core/continual.finetune_step now delegates to

Entry point: `FleetRunSpec(provider="detector", distill=True)` — see
fleet/api.py. The learning curve is read off the in-scan `chosen_rank`
metric (obs/metrics.py) and benchmarked by benchmarks/bench_rank_quality
.fleet_learning_curve.
"""
from repro.learn.loop import (
    LearnState,
    distill_step,
    distill_update,
    finetune_update,
    init_finetune_state,
    init_learn,
    lr_at,
    merged_params,
    optimizer_apply,
    trainable_mask,
)
from repro.learn.loss import distill_full_loss, distill_head_loss
from repro.learn.pairs import (
    PairBuffer,
    harvest_into_buffer,
    init_pair_buffer,
    select_sent_windows,
    teacher_window_targets,
)
from repro.learn.spec import DistillSpec, normalize_distill
