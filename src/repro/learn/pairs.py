"""Training-pair harvesting: sent crops -> per-camera distillation pairs.

The paper's constraint (§3.4) is that distillation runs "with only
camera resources": the teacher only ever grades frames the budget
actually shipped. This module enforces that shape exactly —

  * `select_sent_windows` picks up to `harvest` of this step's SENT
    windows (the chosen orientation first, then descending predicted
    accuracy), so training pairs only come from crops the backend saw;
  * `teacher_window_targets` produces the teacher's detections for those
    windows as static-shape DistillTargets-style tensors, mirroring the
    kernels/cell_rasterize geometry + scene_jax.observe teacher-draw rule
    bit-for-bit (clip -> visibility -> apparent-size ramp -> hashed
    flicker draw), in window-normalized cxcywh;
  * `PairBuffer` is the on-device per-camera ring the pairs land in; the
    student payload (staged post-neck features or patch tokens) is
    gathered from the SAME [F, K] fused forward the ranking used, so
    harvesting costs zero extra renders or backbone passes and total
    training cost scales with shortlist_k, not N*Z.

Every function is row-wise over the fleet axis (no cross-camera
reductions, no shared RNG), so harvesting is fleet-size/shard
independent — tests/test_learn.py pins full-fleet vs per-row equality.
The host-side orientation-balanced `core/continual.ReplayBuffer` remains
as the legacy reference implementation of the paper's replay balancing;
this ring is its in-scan counterpart.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.scene_jax.observe import _BASE_SALT, TeacherArrays, hash01
from repro.scene_jax.scene import SceneFleetParams, SceneSpec, SceneState, \
    kind_mask


class PairBuffer(NamedTuple):
    """Per-camera ring of distillation pairs (device pytree, rides the
    scan carry). `x` is the student payload — post-neck features
    [F, B, g, g, Fd] in head-only mode, patch tokens [F, B, P, D] in
    full-param mode. `weight` is 1.0 for filled slots, 0.0 for empty —
    the loss weighs by it, so idle slots contribute nothing."""
    x: jnp.ndarray          # [F, B, ...] student payload
    boxes: jnp.ndarray      # [F, B, mb, 4] teacher boxes (cxcywh, window)
    classes: jnp.ndarray    # [F, B, mb] int32 teacher classes
    valid: jnp.ndarray      # [F, B, mb] bool per-box validity
    weight: jnp.ndarray     # [F, B] float32 slot fill weight
    ptr: jnp.ndarray        # [F] int32 next write position


def init_pair_buffer(n_cameras: int, buffer: int, payload_shape: tuple,
                     max_boxes: int, dtype=jnp.float32) -> PairBuffer:
    f, b = n_cameras, buffer
    return PairBuffer(
        x=jnp.zeros((f, b) + tuple(payload_shape), dtype),
        boxes=jnp.zeros((f, b, max_boxes, 4), jnp.float32),
        classes=jnp.zeros((f, b, max_boxes), jnp.int32),
        valid=jnp.zeros((f, b, max_boxes), bool),
        weight=jnp.zeros((f, b), jnp.float32),
        ptr=jnp.zeros((f,), jnp.int32))


def select_sent_windows(out, n_zoom: int, harvest: int):
    """FleetStepOut -> the flattened window ids (cell * Z + zoom) worth
    harvesting this step.

    Only SENT cells qualify (paper: the teacher grades shipped frames).
    Priority: the chosen orientation first (it is always sent — rank 0
    clears any k_send >= 1), then descending predicted accuracy;
    lax.top_k's lower-index tie-break keeps the selection deterministic.
    Returns (widx [F, H] int32, ok [F, H] bool) — ok=False rows are
    padding when fewer than `harvest` cells were sent.
    """
    import jax

    f, n = out.sent.shape
    score = jnp.where(out.sent, out.pred_acc, -jnp.inf)
    score = score.at[jnp.arange(f), out.chosen].add(
        jnp.where(out.sent[jnp.arange(f), out.chosen], 10.0, 0.0))
    vals, cells = jax.lax.top_k(score, harvest)             # [F, H]
    ok = jnp.isfinite(vals)
    safe_cells = jnp.where(ok, cells, 0)
    zooms = jnp.take_along_axis(out.zooms, safe_cells, axis=1)
    return (safe_cells * n_zoom + zooms).astype(jnp.int32), ok


def teacher_window_targets(spec: SceneSpec, teach: TeacherArrays,
                           params: SceneFleetParams, sc: SceneState,
                           t: jnp.ndarray, sel_windows: jnp.ndarray,
                           max_boxes: int,
                           cam_salt: jnp.ndarray):
    """Teacher detections for the harvested windows, as static targets.

    sel_windows [F, H, 4] (x0, y0, fw, fh) scene-degree FOVs; t [F] the
    flicker/miss clock frame (the SAME frame the observation pass used);
    cam_salt [F] the per-camera noise salt (state.rng[:, 0]).

    Mirrors the oracle pass exactly: an object is a teacher detection in
    a window when it is >= min_visible there and its hashed flicker draw
    clears the apparent-size response ramp for ANY workload pair of its
    class — the identical rule cell_rasterize counted for acc_true, so
    the student trains on the teacher the controller is graded against.
    Boxes come back window-normalized cxcywh (the clipped extent), the
    `max_boxes` largest first. Returns (boxes [F, H, mb, 4],
    classes [F, H, mb] int32, valid [F, H, mb] bool).
    """
    import jax

    kinds = jnp.asarray(kind_mask(spec))                   # [M]
    cls_match = (teach.cls[:, None] == kinds[None, :])     # [P, M]

    # teacher draw (scene_jax.observe rule: base/bucket flicker mix of
    # the FNV hash, normalized by the plateau; disabled slots never fire)
    cam = cam_salt[:, None, None]                          # [F, 1, 1]
    oid = sc.oid[:, None, :]                               # [F, 1, M]
    salt = teach.salt[None, :, None]                       # [1, P, 1]
    bucket = (t // spec.flicker_bucket)[:, None, None]     # [F, 1, 1]
    draw = ((1.0 - teach.flicker[None, :, None])
            * hash01(oid, salt, cam, jnp.uint32(_BASE_SALT))
            + teach.flicker[None, :, None] * hash01(oid, salt, cam, bucket))
    draw = draw / jnp.maximum(teach.pmax[None, :, None], 1e-6)
    live = params.enabled[:, None, :] & cls_match[None]    # [F, P, M]
    draw_t = jnp.where(live, draw, 2.0)

    # window clipping + visibility (kernels/cell_rasterize geometry)
    x0 = sel_windows[..., 0][:, None, :]                   # [F, 1, H]
    y0 = sel_windows[..., 1][:, None, :]
    fw = sel_windows[..., 2][:, None, :]
    fh = sel_windows[..., 3][:, None, :]
    ox, oy = sc.pos[..., 0], sc.pos[..., 1]                # [F, M]
    ow, oh = sc.size[..., 0], sc.size[..., 1]
    ix0 = jnp.maximum((ox - ow / 2)[..., None], x0)        # [F, M, H]
    ix1 = jnp.minimum((ox + ow / 2)[..., None], x0 + fw)
    iy0 = jnp.maximum((oy - oh / 2)[..., None], y0)
    iy1 = jnp.minimum((oy + oh / 2)[..., None], y0 + fh)
    iw = jnp.maximum(ix1 - ix0, 0.0)
    ih = jnp.maximum(iy1 - iy0, 0.0)
    vis = (iw * ih) / jnp.maximum((ow * oh)[..., None], 1e-9)
    visible = vis >= spec.min_visible

    nw, nh = iw / fw, ih / fh
    apparent = jnp.maximum(nw, nh)
    resp = jnp.clip(
        (apparent[:, None] - teach.a0[None, :, None, None])
        / jnp.maximum((teach.a1 - teach.a0)[None, :, None, None], 1e-6),
        0.0, 1.0)                                          # [F, P, M, H]
    det = (draw_t[..., None] < resp) & visible[:, None]
    det_any = jnp.any(det, axis=1)                         # [F, M, H]

    # window-normalized cxcywh of the clipped extent
    bcx = ((ix0 + ix1) / 2 - x0) / fw
    bcy = ((iy0 + iy1) / 2 - y0) / fh
    boxes_all = jnp.stack([bcx, bcy, nw, nh], axis=-1)     # [F, M, H, 4]

    a_norm = nw * nh
    score = jnp.where(det_any, a_norm, -1.0)               # [F, M, H]
    score = jnp.moveaxis(score, 1, 2)                      # [F, H, M]
    vals, midx = jax.lax.top_k(score, max_boxes)           # [F, H, mb]
    bvalid = vals > 0.0
    f = sc.oid.shape[0]
    af = jnp.arange(f)[:, None, None]
    ah = jnp.arange(sel_windows.shape[1])[None, :, None]
    boxes = jnp.moveaxis(boxes_all, 1, 2)[af, ah, midx]    # [F, H, mb, 4]
    classes = jnp.broadcast_to(kinds[None, None, :],
                               score.shape)[af, ah, midx].astype(jnp.int32)
    return boxes, classes, bvalid


def harvest_into_buffer(buf: PairBuffer, staged: jnp.ndarray,
                        staged_widx: jnp.ndarray, sel_widx: jnp.ndarray,
                        sel_ok: jnp.ndarray, boxes: jnp.ndarray,
                        classes: jnp.ndarray, bvalid: jnp.ndarray
                        ) -> PairBuffer:
    """Ring-write this step's harvested pairs.

    staged [F, K, ...] is the inference pass's student payload;
    staged_widx [F, K] the window ids it covers. Selected windows that
    are not in the staged set (can't happen when the selection comes
    from sent == shortlisted cells, but the code does not rely on it)
    and padding rows (sel_ok=False) write to the out-of-range slot and
    are dropped (`mode="drop"`), so real entries are never clobbered by
    invalid ones. Row-wise per camera: fleet-size/shard independent.
    """
    f, b = buf.weight.shape
    eq = staged_widx[:, :, None] == sel_widx[:, None, :]   # [F, K, H]
    pos = jnp.argmax(eq, axis=1)                           # [F, H]
    found = jnp.any(eq, axis=1) & sel_ok
    af = jnp.arange(f)[:, None]
    payload = staged[af, pos]                              # [F, H, ...]

    offs = (jnp.cumsum(found.astype(jnp.int32), axis=1)
            - found.astype(jnp.int32))
    slot = (buf.ptr[:, None] + offs) % b
    wslot = jnp.where(found, slot, b)                      # b = dropped
    return PairBuffer(
        x=buf.x.at[af, wslot].set(payload, mode="drop"),
        boxes=buf.boxes.at[af, wslot].set(boxes, mode="drop"),
        classes=buf.classes.at[af, wslot].set(classes, mode="drop"),
        valid=buf.valid.at[af, wslot].set(bvalid, mode="drop"),
        weight=buf.weight.at[af, wslot].set(1.0, mode="drop"),
        ptr=((buf.ptr + found.sum(axis=1)) % b).astype(jnp.int32))
