"""One fleet timestep: budget -> shape -> path -> zoom -> rank (Fig. 8).

Faithful fixed-shape reimplementation of MadEyeController.step over a
[F, n_cells] fleet batch. Each stage mirrors its numpy counterpart:

  _plan            core/tradeoff.plan_timestep   (closed form over the
                   static k in [min_send, max_send] instead of a loop)
  shape evolution  core/search via fleet/shape_ops (masked while-loops)
  _walk_one        core/path.PathPlanner.subtree_walk — induced-MST
                   preorder with deterministic stitch/tie rules, vmapped
  _shrink_to_budget core/path.shrink_to_budget
  _zoom            core/zoom.step on per-cell box summary statistics
  _rank            core/rank.predict_workload_accuracy + stable ranking

Tie-breaking matches the numpy implementation (first extremum / lower
cell id / earlier path position), so an F=1 fleet tracks the reference
controller decision for decision; tests/test_fleet_parity.py asserts it.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ewma
from repro.fleet import shape_ops
from repro.fleet.state import (
    NET_DEFAULT_MBPS,
    NET_WINDOW,
    FleetConfig,
    FleetState,
    FleetStatics,
    WorkloadSpec,
)

INF = jnp.inf


class FleetObs(NamedTuple):
    """Per-timestep observation substrate.

    Tables are indexed [n_cells, n_zoom, ...] when the whole fleet shares
    one world (the host-precomputed EpisodeTables path) or
    [F, n_cells, n_zoom, ...] when every camera watches its own scene
    (the device-resident repro.scene_jax provider); the step gathers
    rank-aware. mbps/rtt are [] for a shared link or [F] for per-camera
    network traces. counts/areas/geometry may come from the teacher
    tables, the scene-oracle rasterizer, or the distilled detector's
    scored crops (DetectorProvider) — the step is provider-agnostic,
    which is the whole point of the seam: acc_true is always the
    oracle's grade of the chosen orientation."""
    counts: jnp.ndarray     # [(F,) N, Z, P] approx-model count per pair
    areas: jnp.ndarray      # [(F,) N, Z, P] summed box area per pair
    centroid: jnp.ndarray   # [(F,) N, Z, 2] bbox centroid (scene degrees)
    spread: jnp.ndarray     # [(F,) N, Z] box-center spread
    extent: jnp.ndarray     # [(F,) N, Z] max box side
    nbox: jnp.ndarray       # [(F,) N, Z] box count
    acc_true: jnp.ndarray   # [(F,) N, Z] oracle workload accuracy
    mbps: jnp.ndarray       # [] or [F] network sample this step
    rtt: jnp.ndarray        # [] or [F]


class FleetStepOut(NamedTuple):
    explored: jnp.ndarray   # [F, N] bool
    order: jnp.ndarray      # [F, N] int32 path order (-1 padded)
    n_explored: jnp.ndarray  # [F] int32
    zooms: jnp.ndarray      # [F, N] int32 zoom index per cell
    sent: jnp.ndarray       # [F, N] bool — shipped to the backend
    pred_acc: jnp.ndarray   # [F, N] predicted workload accuracy
    path_time: jnp.ndarray  # [F] seconds
    k_send: jnp.ndarray     # [F] int32
    chosen: jnp.ndarray     # [F] int32 — top-ranked explored cell
    acc_chosen: jnp.ndarray  # [F] oracle accuracy of the chosen cell


# ---------------------------------------------------------------------------
# budget (core/tradeoff.plan_timestep, closed form)
# ---------------------------------------------------------------------------

def _plan(cfg: FleetConfig, harmonic, rtt, train_acc, pred_var):
    risk = (1.0 - train_acc) + pred_var
    # same 1e-4 floor guard as core/tradeoff.frames_to_send (f32 and f64
    # must take the same branch on the 0.20-boundary risk values)
    k_risk = jnp.clip(1 + jnp.floor(risk / 0.20 + 1e-4).astype(jnp.int32),
                      cfg.min_send, cfg.max_send)
    hop_time = cfg.hop_degrees / cfg.rotation_speed
    per_extra = max(hop_time, cfg.approx_infer_s)
    ts = cfg.timestep

    karr = jnp.arange(cfg.min_send, cfg.max_send + 1)
    kf = karr.astype(jnp.float32)[None, :]          # [1, K]
    send_time = rtt[:, None] + (cfg.frame_bytes * 8.0 * kf) \
        / (harmonic[:, None] * 1e6)
    backend = cfg.backend_infer_s * kf
    if cfg.pipelined:
        fits = (send_time <= ts) & (backend <= ts)
        t_arr = jnp.where(
            fits, ts,
            ts - jnp.maximum(0.0, send_time - ts)
            - jnp.maximum(0.0, backend - ts))
    else:
        t_arr = ts - send_time - backend
    extra = (t_arr - cfg.approx_infer_s) / per_extra
    mc_arr = jnp.where(
        t_arr > 0,
        1 + jnp.floor(jnp.maximum(0.0, extra) + 1e-4).astype(jnp.int32),
        1)                                          # [F, K]
    feasible = ((mc_arr >= karr[None, :])
                & (karr[None, :] <= k_risk[:, None])
                & (karr[None, :] > cfg.min_send))
    any_f = jnp.any(feasible, axis=-1)
    best = jnp.max(jnp.where(feasible, karr[None, :], -1), axis=-1)
    pos = jnp.where(any_f, best - cfg.min_send, 0)
    k_send = jnp.where(any_f, best, cfg.min_send).astype(jnp.int32)
    t_explore = jnp.take_along_axis(t_arr, pos[:, None], -1)[:, 0]
    mc = jnp.take_along_axis(mc_arr, pos[:, None], -1)[:, 0]
    max_cells = jnp.where(any_f, mc, jnp.maximum(mc, cfg.min_send))
    return k_send, jnp.maximum(t_explore, 0.0), max_cells


# ---------------------------------------------------------------------------
# reachability: induced-MST preorder walk + shrink to the time budget
# ---------------------------------------------------------------------------

def _walk_one(statics: FleetStatics, mask, start):
    """core/path.subtree_walk for one camera. mask [N] bool, start [].

    Returns (order [N] int32 padded with -1, count [], path_time_deg []).
    path_time_deg is in degrees (caller divides by rotation speed).
    """
    n = mask.shape[0]
    dist = statics.dist
    m = jnp.sum(mask)

    masked_d = jnp.where(mask, dist[start], INF)
    start2 = jnp.where(mask[start], start, jnp.argmin(masked_d))
    induced = statics.mst_adj & mask[:, None] & mask[None, :]

    # stitch the components of the induced forest to start2's component
    # by the cheapest (row-major first) cross edge; usually 0 iterations
    seed = jax.nn.one_hot(start2, n, dtype=jnp.bool_)
    done = shape_ops.flood_reach(mask, seed, induced)

    def stitch_cond(carry):
        done, _ = carry
        return jnp.any(mask & ~done)

    def stitch_body(carry):
        done, extra = carry
        rest = mask & ~done
        cross = jnp.where(done[:, None] & rest[None, :], dist, INF)
        idx = jnp.argmin(cross.reshape(-1))
        u, v = idx // n, idx % n
        done = done | shape_ops.flood_reach(
            rest, jax.nn.one_hot(v, n, dtype=jnp.bool_), induced)
        extra = extra.at[u, v].set(True).at[v, u].set(True)
        return done, extra

    done, extra = lax.while_loop(
        stitch_cond, stitch_body, (done, jnp.zeros((n, n), bool)))
    tree = induced | extra

    # preorder DFS, children visited nearest-first (ties: lower cell id).
    # The push ordering (descending distance key) is static per grid
    # (statics.nbr_order), so each sequential loop iteration is gathers +
    # a cumsum — no sort.
    def dfs_cond(carry):
        return carry[1] > 0                         # stack non-empty

    def dfs_body(carry):
        stack, top, seen, order, cnt = carry
        u = stack[top - 1]
        top2 = top - 1
        seen2 = seen.at[u].set(True)
        order2 = order.at[cnt].set(u)
        cnt2 = cnt + 1

        row = statics.nbr_order[u]                  # push order (desc key)
        push = tree[u][row] & ~seen2[row]
        slots = jnp.where(push,
                          top2 + jnp.cumsum(push) - 1, n + 1)
        stack2 = stack.at[slots].set(row, mode="drop")
        k = jnp.sum(push)
        return (stack2, top2 + k.astype(jnp.int32), seen2, order2, cnt2)

    stack0 = jnp.zeros(n, jnp.int32).at[0].set(start2.astype(jnp.int32))
    top0 = (m > 0).astype(jnp.int32)
    order0 = jnp.full(n, -1, jnp.int32)
    _, _, _, order, cnt = lax.while_loop(
        dfs_cond, dfs_body,
        (stack0, top0, jnp.zeros(n, bool), order0,
         jnp.zeros((), jnp.int32)))

    ordc = jnp.maximum(order, 0)
    prev = jnp.concatenate([start[None].astype(jnp.int32), ordc[:-1]])
    hops = dist[prev, ordc]
    t_deg = jnp.sum(jnp.where(jnp.arange(n) < cnt, hops, 0.0))
    return order, cnt, t_deg


_walk = jax.vmap(_walk_one, in_axes=(None, 0, 0))


def _shrink_to_budget(cfg: FleetConfig, statics: FleetStatics, mask, start,
                      labels, budget_s, per_cell):
    """core/path.shrink_to_budget, batched. Returns (mask, order, cnt, t).

    The first walk runs outside the loop: when every camera's shape is
    already coverable (the common case) no removal work is issued at all.
    """
    f, n = mask.shape

    def feasible(mask, cnt, t):
        return (t + per_cell * cnt <= budget_s) | (jnp.sum(mask, -1) <= 1)

    order, cnt, t_deg = _walk(statics, mask, start)
    t = t_deg / cfg.rotation_speed
    done = feasible(mask, cnt, t)

    def cond(c):
        return jnp.any(~c["done"])

    def body(c):
        mask, done = c["mask"], c["done"]
        T = shape_ops.first_removable(mask, labels, statics.neighbor8)
        mask = jnp.where(~done[:, None],
                         mask & ~shape_ops._onehot(T, n), mask)
        order, cnt, t_deg = _walk(statics, mask, start)
        t = t_deg / cfg.rotation_speed
        ok = feasible(mask, cnt, t)
        newly = ~done & ok
        return {"mask": mask, "done": done | ok,
                "order": jnp.where(newly[:, None], order, c["order"]),
                "cnt": jnp.where(newly, cnt, c["cnt"]),
                "t": jnp.where(newly, t, c["t"])}

    out = lax.while_loop(cond, body, {"mask": mask, "done": done,
                                      "order": order, "cnt": cnt, "t": t})
    return out["mask"], out["order"], out["cnt"], out["t"]


# ---------------------------------------------------------------------------
# zoom (core/zoom.step on summary statistics)
# ---------------------------------------------------------------------------

def _zoom(cfg: FleetConfig, statics: FleetStatics, state: FleetState,
          explored):
    """Returns (zoom_idx, zoomed_since) advanced for explored cells."""
    dt = cfg.timestep
    zi, zs = state.zoom_idx, state.zoomed_since
    timer = (zi > 0) & (zs + dt >= cfg.zoom_out_after)

    cluster = state.nb_spread + state.nb_extent
    off = jnp.linalg.norm(state.nb_centroid - statics.centers[None], axis=-1)
    z_geo = jnp.zeros_like(zi)
    for i, z in enumerate(cfg.zoom_levels):
        fw = cfg.fov_scale * cfg.pan_step / z
        fh = cfg.fov_scale * cfg.tilt_step / z
        half = min(fw, fh) / 2.0
        fits = (cluster + off) <= cfg.margin * half
        z_geo = jnp.where(fits, i, z_geo)

    z_new = jnp.where(timer | ~state.nb_has, 0, z_geo).astype(jnp.int32)
    zs_new = jnp.where((z_new > 0) & (zi > 0), zs + dt, 0.0)
    zi_out = jnp.where(explored, z_new, zi)
    zs_out = jnp.where(explored, zs_new, zs)
    return zi_out, zs_out


# ---------------------------------------------------------------------------
# rank (core/rank, relative to the explored set)
# ---------------------------------------------------------------------------

def _rank(wl: WorkloadSpec, counts_g, areas_g, visits, explored):
    """counts_g/areas_g [F, N, P] at the chosen zoom; visits [F, N]
    (pre-update EWMA seen); explored [F, N]. -> pred_acc [F, N]."""
    total = None
    for q in range(len(wl.pair_idx)):
        cnt = jnp.where(explored, counts_g[..., wl.pair_idx[q]], 0.0)
        area = jnp.where(explored, areas_g[..., wl.pair_idx[q]], 0.0)
        task = wl.task_id[q]
        if task == 0:          # binary
            s = (cnt > 0).astype(jnp.float32)
        elif task == 1:        # count
            m = jnp.max(cnt, axis=-1, keepdims=True)
            s = jnp.where(m > 0, cnt / jnp.maximum(m, 1e-9), 0.0)
        elif task == 2:        # detect: count + area proxy
            m = jnp.max(cnt, axis=-1, keepdims=True)
            cs = jnp.where(m > 0, cnt / jnp.maximum(m, 1e-9), 0.0)
            am = jnp.max(area, axis=-1, keepdims=True)
            asc = jnp.where(am > 0, area / jnp.maximum(am, 1e-9), 0.0)
            s = 0.7 * cs + 0.3 * asc
        else:                  # agg_count: novelty-modulated
            m = jnp.max(cnt, axis=-1, keepdims=True)
            base = jnp.where(m > 0, cnt / jnp.maximum(m, 1e-9), 0.0)
            novelty = 1.0 / jnp.sqrt(1.0 + visits)
            s = base * (1.0 + novelty)
            sm = jnp.max(jnp.where(explored, s, 0.0), axis=-1, keepdims=True)
            s = jnp.where(sm > 0, s / jnp.maximum(sm, 1e-9), s)
        s = jnp.where(explored, s, 0.0)
        total = s if total is None else total + s
    return total / len(wl.pair_idx)


def gather_at_zoom(x: jnp.ndarray, zoom_idx: jnp.ndarray,
                   trailing: int = 0) -> jnp.ndarray:
    """Gather an observation table at each cell's chosen zoom.

    x is [N, Z, ...] (fleet-shared tables) or [F, N, Z, ...] (per-camera
    scenes) with `trailing` extra dims; zoom_idx [F, N]. Returns
    [F, N, ...]. The step's observe-at-chosen-zoom gather, shared with
    the in-scan metrics (repro.obs.metrics grades the chosen cell
    against the same oracle row the step saw).
    """
    f, n = zoom_idx.shape
    cell_ax = jnp.arange(n)[None, :]
    if x.ndim == 2 + trailing:                      # shared across fleet
        return x[cell_ax, zoom_idx]
    return x[jnp.arange(f)[:, None], cell_ax, zoom_idx]


# ---------------------------------------------------------------------------
# the timestep
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "wl"))
def fleet_step(cfg: FleetConfig, wl: WorkloadSpec, statics: FleetStatics,
               state: FleetState, obs: FleetObs
               ) -> tuple[FleetState, FleetStepOut]:
    f, n = state.shape.shape
    arange_f = jnp.arange(f)

    # 0. network observation (harmonic-mean window, core/tradeoff)
    slot = state.net_count % NET_WINDOW
    samples = state.net_samples.at[arange_f, slot].set(
        jnp.maximum(jnp.broadcast_to(obs.mbps, (f,)), 1e-3))
    net_count = state.net_count + 1
    n_s = jnp.minimum(net_count, NET_WINDOW)
    inv = jnp.where(jnp.arange(NET_WINDOW)[None, :] < n_s[:, None],
                    1.0 / jnp.maximum(samples, 1e-9), 0.0)
    harmonic = jnp.where(n_s > 0, n_s / jnp.maximum(inv.sum(-1), 1e-9),
                         NET_DEFAULT_MBPS)
    rtt = jnp.broadcast_to(obs.rtt, (f,))

    # 1. budget
    k_send, t_explore, max_cells = _plan(cfg, harmonic, rtt,
                                         state.train_acc, state.pred_var)

    # 2. shape: reseed on empty scene, else evolve + resize (+ scout)
    labels = ewma.labels(state.ewma, delta_weight=cfg.delta_weight)
    staleness = (state.step_idx[:, None] - state.last_visit).astype(
        jnp.float32)
    prev = state.shape

    reseed_center = jnp.argmax(labels + 1e-4 * staleness, axis=-1)
    shape_reseed = shape_ops.seed_shape(statics, cfg, max_cells,
                                        reseed_center)

    evolved = shape_ops.evolve_shape(cfg, statics, prev, labels,
                                     state.centroids, state.has_boxes)
    evolved = shape_ops.resize_shape(cfg, statics, evolved, labels,
                                     state.centroids, state.has_boxes,
                                     max_cells)
    if cfg.scout_every:
        scout_now = ((max_cells == 1)
                     & (state.step_idx % cfg.scout_every
                        == cfg.scout_every - 1))
        score = labels + 1e-3 * jnp.sqrt(jnp.maximum(staleness, 0.0))
        score = jnp.where(evolved, -INF, score)
        scout = jnp.argmax(score, axis=-1)
        evolved = jnp.where(scout_now[:, None],
                            shape_ops._onehot(scout, n), evolved)

    reseed = ~state.saw_objects
    shape = jnp.where(reseed[:, None], shape_reseed, evolved)
    newly = jnp.where(reseed[:, None], shape_reseed, shape & ~prev)
    zoom_idx = jnp.where(newly, 0, state.zoom_idx)
    zoomed_since = jnp.where(newly, 0.0, state.zoomed_since)
    state = state._replace(zoom_idx=zoom_idx, zoomed_since=zoomed_since)

    # 3. reachability: shrink until coverable in the exploration budget
    hop_s = cfg.pan_step / cfg.rotation_speed
    per_cell = max(0.0, cfg.approx_infer_s - hop_s)
    budget_s = jnp.maximum(t_explore - cfg.approx_infer_s,
                           cfg.approx_infer_s + hop_s)
    shape, order, cnt, path_time = _shrink_to_budget(
        cfg, statics, shape, state.current_cell, labels, budget_s, per_cell)
    explored = shape

    # path position per cell (for rank tie-breaking + feedback argmaxes)
    ordc = jnp.maximum(order, 0)
    idx = jnp.where(jnp.arange(n)[None, :] < cnt[:, None], ordc, n)
    pos = jnp.full((f, n), n, jnp.int32).at[
        arange_f[:, None], idx].set(
        jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (f, n)),
        mode="drop")

    # 4. zoom per explored cell (driven by last timestep's boxes)
    zoom_idx, zoomed_since = _zoom(cfg, statics, state, explored)

    # 5. observe at (cell, chosen zoom); tables are either fleet-shared
    # [N, Z, ...] or per-camera [F, N, Z, ...] (the scene-backed provider
    # generates the latter inside the scan) — rank decides the gather
    def at_zoom(x, trailing=0):
        return gather_at_zoom(x, zoom_idx, trailing)

    counts_g = at_zoom(obs.counts, 1)               # [F, N, P]
    areas_g = at_zoom(obs.areas, 1)
    o_centroid = at_zoom(obs.centroid, 1)           # [F, N, 2]
    o_spread = at_zoom(obs.spread)
    o_extent = at_zoom(obs.extent)
    o_has = at_zoom(obs.nbox) > 0
    true_g = at_zoom(obs.acc_true)                  # [F, N]

    # 6. rank explored orientations by predicted workload accuracy
    visits = state.ewma.seen
    pred = _rank(wl, counts_g, areas_g, visits, explored)

    # stable ranking by (-pred, path position) — matches rank_orientations
    # on the path-ordered numpy arrays. srank[c] = number of explored
    # cells strictly ahead of c; pairwise compare beats two sorts here.
    better = ((pred[:, None, :] > pred[:, :, None])
              | ((pred[:, None, :] == pred[:, :, None])
                 & (pos[:, None, :] < pos[:, :, None])))
    srank = jnp.sum(better & explored[:, None, :], axis=-1,
                    dtype=jnp.int32)                # rank of c among explored
    sent = explored & (srank < k_send[:, None])

    # 7. state updates (EWMA labels, stale decay, geometry, feedback)
    step_idx = state.step_idx + 1
    last_visit = jnp.where(explored, step_idx[:, None], state.last_visit)
    ew = ewma.update(state.ewma, explored, pred)
    ew = ewma.decay_unvisited(ew, explored, rate=cfg.stale_decay)

    has_boxes = jnp.where(explored, o_has, state.has_boxes)
    centroids = jnp.where((explored & o_has)[..., None], o_centroid,
                          state.centroids)
    nb_centroid = jnp.where(explored[..., None], o_centroid,
                            state.nb_centroid)
    nb_spread = jnp.where(explored, o_spread, state.nb_spread)
    nb_extent = jnp.where(explored, o_extent, state.nb_extent)
    nb_has = jnp.where(explored, o_has, state.nb_has)
    saw_objects = jnp.any(explored & o_has, axis=-1)

    # backend feedback: rank agreement on the truly-best explored cell
    k_cells = cnt
    mx_pred = jnp.max(jnp.where(explored, pred, -INF), axis=-1,
                      keepdims=True)
    best_pred = jnp.argmin(
        jnp.where(explored & (pred == mx_pred), pos, n + 1), axis=-1)
    mx_true = jnp.max(jnp.where(explored, true_g, -INF), axis=-1,
                      keepdims=True)
    best_true = jnp.argmin(
        jnp.where(explored & (true_g == mx_true), pos, n + 1), axis=-1)
    agree = (best_pred == best_true).astype(jnp.float32)
    train_acc = jnp.where(k_cells > 1,
                          0.9 * state.train_acc + 0.1 * agree,
                          state.train_acc)

    kf = jnp.maximum(k_cells, 1).astype(jnp.float32)
    mean_p = jnp.sum(jnp.where(explored, pred, 0.0), -1) / kf
    var_p = jnp.sum(jnp.where(explored, (pred - mean_p[:, None]) ** 2, 0.0),
                    -1) / kf
    pred_var = jnp.where(k_cells > 1, var_p, 0.0)

    current_cell = jnp.where(
        cnt > 0, ordc[arange_f, jnp.maximum(cnt - 1, 0)],
        state.current_cell).astype(jnp.int32)

    new_state = FleetState(
        ewma=ew, shape=shape, current_cell=current_cell,
        zoom_idx=zoom_idx, zoomed_since=zoomed_since,
        centroids=centroids, has_boxes=has_boxes,
        nb_centroid=nb_centroid, nb_spread=nb_spread,
        nb_extent=nb_extent, nb_has=nb_has,
        train_acc=train_acc, pred_var=pred_var,
        saw_objects=saw_objects, step_idx=step_idx,
        last_visit=last_visit, net_samples=samples,
        net_count=net_count, rtt=rtt, rng=state.rng)
    out = FleetStepOut(explored=explored, order=order, n_explored=cnt,
                       zooms=zoom_idx, sent=sent, pred_acc=pred,
                       path_time=path_time, k_send=k_send,
                       chosen=best_pred.astype(jnp.int32),
                       acc_chosen=true_g[arange_f, best_pred])
    return new_state, out
