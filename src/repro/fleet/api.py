"""Unified fleet experiment API: one declarative entry for every provider.

MadEye's core loop (search -> approximate -> select, paper §3) used to be
wired three times over — the tables, scene, and detector observation
paths each had their own scan wrapper, engine function, and CLI flag.
This module is the single composable entry point instead:

  * `ObservationProvider` — the protocol every observation source
    implements (init_carry / scan_xs / observe / n_steps / shard). The
    shipped providers live in runner.py; new scenarios plug in through
    `register_provider` rather than forking the episode.
  * the provider registry — string-keyed factories (`tables`, `scene`,
    `detector`) with one uniform signature, open for future providers
    (in-scan distillation, camera drift, RL-tuned configs).
  * `FleetRunSpec` — a declarative, JSON-round-trippable description of
    a fleet experiment: provider name + kwargs, workload, budget,
    episode length, seed, and a `ShardSpec` that plumbs mesh placement
    through the public API.
  * `run_fleet(spec) -> FleetResult` — build the provider, run the ONE
    jit'd episode scan (runner._episode), and return a typed result
    (per-step accuracies, chosen orientations, frames sent, timings)
    that also round-trips through JSON.

    >>> spec = FleetRunSpec(provider="scene", n_cameras=4, n_steps=32)
    >>> result = run_fleet(spec)
    >>> result.accuracy, result.frames_sent[-1]

`prepare_fleet_run` exposes the build/run split for benchmarks that time
compile vs steady-state themselves.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

from repro.core import DEFAULT_GRID, OrientationGrid, Query, Workload
from repro.core.tradeoff import BudgetConfig
from repro.fleet.runner import (
    make_detector_provider,
    make_scene_provider,
    make_tables_provider,
    run_fleet_episode,
)
from repro.fleet.state import (
    FleetConfig,
    FleetState,
    FleetStatics,
    WorkloadSpec,
    fleet_config,
    fleet_statics,
    workload_spec,
)
from repro.fleet.step import FleetStepOut
from repro.obs import MetricsSpec, span

# the serving launcher's default 4-query workload, as spec-friendly
# (model, object, task) triples — one definition shared by serve.py and
# the benchmarks so "the default workload" can't drift between entry
# points
DEFAULT_QUERIES = (
    ("yolov4", "person", "count"),
    ("ssd", "car", "detect"),
    ("frcnn", "person", "binary"),
    ("tiny-yolov4", "person", "agg_count"),
)


@runtime_checkable
class ObservationProvider(Protocol):
    """What the unified episode scan needs from an observation source.

    Implementations must also be jax pytrees (register static config as
    aux_data) so the jitted scan can close over them — see runner.py's
    EpisodeTables / SceneProvider / DetectorProvider.
    """

    @property
    def n_steps(self) -> int:
        """Episode length this provider can serve."""
        ...

    def init_carry(self, state: FleetState):
        """Provider-owned scan carry (scene state, model params, ...)."""
        ...

    def scan_xs(self):
        """Pytree of per-step scanned inputs; leaves lead with [E]."""
        ...

    def observe(self, cfg: FleetConfig, wl: WorkloadSpec, carry,
                state: FleetState, xs):
        """(carry, state, xs) -> (new carry, FleetObs) for one step."""
        ...

    def shard(self, mesh):
        """Place fleet-axis leaves on the mesh `data` axis."""
        ...


# ---------------------------------------------------------------------------
# provider registry
# ---------------------------------------------------------------------------

# factory signature: (grid, workload, cfg, *, n_cameras, n_steps, seed,
# **kwargs) -> (provider, FleetState)
ProviderFactory = Callable[..., tuple]

_PROVIDERS: dict[str, ProviderFactory] = {}


def register_provider(name: str, factory: ProviderFactory) -> None:
    """Register an observation-provider factory under a spec name."""
    _PROVIDERS[name] = factory


def provider_factory(name: str) -> ProviderFactory:
    if name not in _PROVIDERS:
        raise KeyError(
            f"unknown observation provider {name!r}; available: "
            f"{', '.join(sorted(_PROVIDERS))}")
    return _PROVIDERS[name]


def available_providers() -> tuple[str, ...]:
    return tuple(sorted(_PROVIDERS))


register_provider("tables", make_tables_provider)
register_provider("scene", make_scene_provider)
register_provider("detector", make_detector_provider)


# ---------------------------------------------------------------------------
# declarative run specification
# ---------------------------------------------------------------------------

def _jsonable(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()
    raise TypeError(f"{type(x).__name__} is not JSON-serializable")


@dataclass(frozen=True)
class ShardSpec:
    """Mesh placement for the fleet axis, as data instead of a loose
    helper: `build_mesh` resolves to a launch/mesh.py mesh and the
    episode shards every provider/state fleet axis over its `data` axis.

    kind "none" runs unsharded; "debug" builds an n_data x n_model mesh
    from whatever local devices exist; "production" builds the 256-chip
    pod mesh (multi_pod=True: 2 pods)."""
    kind: str = "none"
    n_data: int = 1
    n_model: int = 1
    multi_pod: bool = False

    def build_mesh(self):
        from repro.launch import mesh as mesh_mod

        if self.kind == "none":
            return None
        if self.kind == "debug":
            return mesh_mod.make_debug_mesh(self.n_data, self.n_model)
        if self.kind == "production":
            return mesh_mod.make_production_mesh(multi_pod=self.multi_pod)
        raise ValueError(f"unknown ShardSpec.kind {self.kind!r} "
                         f"(none | debug | production)")


@dataclass(frozen=True)
class FleetRunSpec:
    """Everything that defines one fleet experiment, declaratively.

    The spec is JSON-round-trippable (`to_json`/`from_json`) whenever
    provider_kwargs values are JSON-native (numbers, strings, lists —
    numpy arrays serialize as lists); in-memory-only kwargs like a
    prebuilt `video=` still work through `run_fleet` but won't survive
    serialization."""
    provider: str = "scene"
    n_cameras: int = 4
    n_steps: int | None = 32
    seed: int = 0
    workload: tuple = DEFAULT_QUERIES   # ((model, obj, task), ...)
    budget: dict = field(default_factory=dict)  # BudgetConfig overrides
    grid: dict = field(default_factory=dict)    # OrientationGrid overrides
    provider_kwargs: dict = field(default_factory=dict)
    shard: ShardSpec | None = None
    # candidate-sparse fast path: how many of the N*Z windows each
    # camera renders + scores per step (providers that run a per-window
    # model honor it — `detector` does; None = provider default, i.e.
    # exhaustive). First-class rather than a provider_kwarg because it
    # is THE accuracy-vs-cost knob a sweep varies (paper §3.3's
    # "fruitful subset").
    shortlist_k: int | None = None
    # in-scan telemetry (repro.obs): None/False = off — the episode
    # compiles to the exact metrics-free program; True = full
    # MetricsSpec; a dict/MetricsSpec picks metric families. Like
    # `shard`, normalized to the dataclass on construction so the spec
    # stays JSON-round-trippable.
    metrics: MetricsSpec | None = None
    # in-scan continual distillation (repro.learn, paper §3.4):
    # None/False = frozen params — the episode compiles to the exact
    # pre-learning program; True = default DistillSpec; a
    # dict/DistillSpec picks optimizer/lr/cadence/ring. Detector
    # provider only (it owns the per-window model). Normalized like
    # `metrics`, so the spec stays JSON-round-trippable.
    distill: Any = None

    def __post_init__(self):
        from repro.learn.spec import normalize_distill

        object.__setattr__(
            self, "workload",
            tuple(tuple(q) for q in self.workload))
        if isinstance(self.shard, dict):
            object.__setattr__(self, "shard", ShardSpec(**self.shard))
        m = self.metrics
        if m is True:
            m = MetricsSpec()
        elif m is False:
            m = None
        elif isinstance(m, dict):
            m = MetricsSpec(**m)
        if m is not None and not m.enabled:
            m = None
        object.__setattr__(self, "metrics", m)
        object.__setattr__(self, "distill",
                           normalize_distill(self.distill))

    # -- object views ---------------------------------------------------
    def grid_obj(self) -> OrientationGrid:
        return OrientationGrid(**self.grid) if self.grid else DEFAULT_GRID

    def budget_obj(self) -> BudgetConfig:
        return BudgetConfig(**self.budget)

    def workload_obj(self) -> Workload:
        return Workload(tuple(Query(*q) for q in self.workload))

    @classmethod
    def from_objects(cls, provider: str, *, n_cameras: int,
                     n_steps: int | None = None, seed: int = 0,
                     grid: OrientationGrid | None = None,
                     workload: Workload | None = None,
                     budget: BudgetConfig | None = None,
                     shard: ShardSpec | None = None,
                     shortlist_k: int | None = None,
                     metrics: MetricsSpec | bool | None = None,
                     distill: Any = None,
                     **provider_kwargs) -> "FleetRunSpec":
        """Build a spec from the in-memory config objects the rest of
        the codebase passes around (the engine shims do)."""
        return cls(
            provider=provider, n_cameras=n_cameras, n_steps=n_steps,
            seed=seed,
            workload=DEFAULT_QUERIES if workload is None else tuple(
                (q.model, q.obj, q.task) for q in workload.queries),
            grid={} if grid is None else dataclasses.asdict(grid),
            budget={} if budget is None else dataclasses.asdict(budget),
            provider_kwargs=provider_kwargs, shard=shard,
            shortlist_k=shortlist_k, metrics=metrics, distill=distill)

    # -- JSON round trip ------------------------------------------------
    def to_json(self, **dumps_kwargs) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, default=_jsonable, **dumps_kwargs)

    @classmethod
    def from_json(cls, s: str) -> "FleetRunSpec":
        return cls(**json.loads(s))


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------

@dataclass
class PreparedFleetRun:
    """A spec resolved to runnable pieces: provider built, configs
    derived, mesh placed. `episode()` runs the unified scan — call it
    repeatedly to separate compile from steady-state (benchmarks do)."""
    spec: FleetRunSpec
    cfg: FleetConfig
    wl: WorkloadSpec
    statics: FleetStatics
    state: FleetState
    provider: Any
    mesh: Any
    build_s: float

    def episode(self, provider=None, state=None, metrics=None):
        """Run the unified scan. With metrics enabled (spec.metrics, or
        the `metrics` override — benchmarks A/B the same prepared run)
        returns (state, out, FleetMetrics dict); (state, out)
        otherwise."""
        return run_fleet_episode(
            self.cfg, self.wl, self.statics,
            self.state if state is None else state,
            self.provider if provider is None else provider,
            mesh=self.mesh,
            metrics=self.spec.metrics if metrics is None else metrics)


def prepare_fleet_run(spec: FleetRunSpec, *, mesh=None) -> PreparedFleetRun:
    """Resolve a FleetRunSpec: registry lookup, provider construction,
    mesh placement — everything up to (but not including) the scan.
    An explicit `mesh` overrides spec.shard."""
    grid = spec.grid_obj()
    workload = spec.workload_obj()
    cfg = fleet_config(grid, spec.budget_obj())
    factory = provider_factory(spec.provider)
    kwargs = dict(spec.provider_kwargs)
    if spec.shortlist_k is not None:
        # first-class fast-path knob; factories that don't take it (the
        # tables/scene providers have no per-window model) fail loudly
        kwargs["shortlist_k"] = spec.shortlist_k
    if spec.distill is not None:
        # in-scan distillation — like shortlist_k, factories without a
        # per-window model to train reject it loudly
        kwargs["distill"] = spec.distill
    t0 = time.perf_counter()
    with span("fleet/build", provider=spec.provider,
              n_cameras=spec.n_cameras):
        provider, state = factory(
            grid, workload, cfg, n_cameras=spec.n_cameras,
            n_steps=spec.n_steps, seed=spec.seed, **kwargs)
    build_s = time.perf_counter() - t0
    if mesh is None and spec.shard is not None:
        mesh = spec.shard.build_mesh()
    return PreparedFleetRun(
        spec=spec, cfg=cfg, wl=workload_spec(workload),
        statics=fleet_statics(grid), state=state, provider=provider,
        mesh=mesh, build_s=build_s)


@dataclass
class FleetResult:
    """Typed result of one fleet episode.

    Host-side summaries (JSON-round-trippable) plus, when produced by
    `run_fleet`, the raw device outputs: final `state` (FleetState),
    `out` (FleetStepOut, leaves [E, F, ...]) and — with spec.metrics
    enabled — `metrics` (FleetMetrics dict, leaves [E, ...]); those,
    plus the `learned` checkpoint handle of distillation runs, are
    dropped by `to_json`/`from_json`."""
    spec: FleetRunSpec
    n_cameras: int
    n_steps: int
    accuracy: float             # mean oracle grade of chosen orientations
    acc_per_step: tuple         # [E] fleet-mean oracle accuracy
    chosen: tuple               # [E][F] chosen orientation cell ids
    frames_sent: tuple          # [E] frames shipped fleet-wide
    mean_shape: float           # mean explored-shape size
    timings: dict               # build_s, compile_s, steady_s, episode_s
    # spec.distill runs only: [E] fleet-mean in-scan distill loss over
    # the cameras that updated that step (-1.0 = off-cadence/idle step)
    distill_loss: tuple | None = None
    state: FleetState | None = None
    out: FleetStepOut | None = None
    metrics: dict | None = None
    # spec.distill runs only: (provider, final scan carry) — the learned
    # per-camera params live in the carry; device-side, not serialized
    learned: Any = None

    def learned_params(self, camera: int | None = 0):
        """Full detector params with camera `camera`'s learned subtree
        merged in (None keeps the leading fleet axis on trained leaves).
        Distillation runs only."""
        if self.learned is None:
            raise ValueError(
                "no learned params: run with FleetRunSpec(distill=...)")
        provider, carry = self.learned
        return provider.learned_params(carry, camera=camera)

    def save_learned_params(self, path: str, camera: int = 0) -> str:
        """Checkpoint one camera's distilled detector as a
        `save_detector_params` .npz (loadable via `det_params="..."`)."""
        from repro.fleet.runner import save_detector_params

        return save_detector_params(path, self.learned_params(camera))

    @property
    def camera_steps_per_s(self) -> float:
        # steady-state throughput: jit compile is a one-off cost, so it
        # must not dilute the rate (older results only carry episode_s)
        t = self.timings.get("steady_s",
                             self.timings.get("episode_s", 0.0))
        return self.n_cameras * self.n_steps / max(t, 1e-9)

    def to_json(self, **dumps_kwargs) -> str:
        # drop the device pytrees BEFORE asdict: asdict deep-copies every
        # leaf it recurses into, which for state/out/metrics/learned
        # would be a full device->host copy of all per-step outputs (and
        # model params) just to discard it
        d = dataclasses.asdict(
            dataclasses.replace(self, state=None, out=None, metrics=None,
                                learned=None))
        d.pop("state"), d.pop("out"), d.pop("metrics"), d.pop("learned")
        d["spec"] = json.loads(self.spec.to_json())
        return json.dumps(d, default=_jsonable, **dumps_kwargs)

    @classmethod
    def from_json(cls, s: str) -> "FleetResult":
        d = json.loads(s)
        d["spec"] = FleetRunSpec(**d["spec"])
        d["acc_per_step"] = tuple(d["acc_per_step"])
        d["chosen"] = tuple(tuple(c) for c in d["chosen"])
        d["frames_sent"] = tuple(d["frames_sent"])
        if d.get("distill_loss") is not None:
            d["distill_loss"] = tuple(d["distill_loss"])
        return cls(**d)


def run_fleet(spec: FleetRunSpec, *, mesh=None) -> FleetResult:
    """THE fleet entry point: spec in, typed result out.

    Builds the named provider through the registry, AOT-lowers and
    compiles the ONE jit'd episode scan (timed as
    timings["compile_s"]), then executes the compiled program (timed as
    timings["steady_s"]). timings["episode_s"] stays their sum for
    back-compat; `camera_steps_per_s` is computed from steady_s alone
    so compile never dilutes throughput. Sharded per spec.shard /
    `mesh`; spec.metrics turns on the in-scan FleetMetrics, attached as
    `result.metrics`."""
    import jax

    from repro.fleet.runner import _episode, shard_fleet

    prep = prepare_fleet_run(spec, mesh=mesh)
    state, provider = prep.state, prep.provider
    if prep.mesh is not None:
        state = shard_fleet(state, prep.mesh)
        provider = provider.shard(prep.mesh)
    mspec = spec.metrics

    # explicit AOT split: lower+compile is the one-off cost, the
    # compiled call is steady-state (static argnames — cfg, wl, the
    # MetricsSpec — are baked in and omitted from the compiled call)
    t0 = time.perf_counter()
    with span("fleet/compile", provider=spec.provider,
              metrics=mspec is not None):
        compiled = _episode.lower(
            prep.cfg, prep.wl, prep.statics, state, provider,
            metrics=mspec).compile()
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    with span("fleet/steady", provider=spec.provider,
              n_cameras=spec.n_cameras):
        res = jax.block_until_ready(compiled(prep.statics, state, provider))
    steady_s = time.perf_counter() - t0

    learns = getattr(provider, "learns", False)
    distill_loss = learned = None
    if learns:
        state, (out, ex), fc = res
        fleet_metrics = ex["metrics"] if mspec is not None else None
        # fleet-mean loss over the cameras that actually updated each
        # step; keep the -1.0 sentinel for off-cadence/idle steps
        loss = np.asarray(ex["learn"]["loss"], np.float32)      # [E, F]
        upd = loss >= 0.0
        nupd = upd.sum(axis=1)
        distill_loss = tuple(
            float(v) for v in np.where(
                nupd > 0,
                (loss * upd).sum(axis=1) / np.maximum(nupd, 1), -1.0))
        learned = (provider, fc)
    elif mspec is not None:
        state, (out, ex) = res
        fleet_metrics = ex["metrics"]
    else:
        state, out = res
        fleet_metrics = None

    acc = np.asarray(out.acc_chosen, np.float32)        # [E, F]
    sent = np.asarray(out.sent)                         # [E, F, N]
    return FleetResult(
        spec=spec, n_cameras=spec.n_cameras,
        n_steps=int(acc.shape[0]),
        accuracy=float(acc.mean()),
        acc_per_step=tuple(float(a) for a in acc.mean(axis=1)),
        chosen=tuple(tuple(int(c) for c in row)
                     for row in np.asarray(out.chosen)),
        frames_sent=tuple(int(s) for s in sent.sum(axis=(1, 2))),
        mean_shape=float(np.asarray(out.n_explored, np.float32).mean()),
        timings={"build_s": prep.build_s, "compile_s": compile_s,
                 "steady_s": steady_s,
                 "episode_s": compile_s + steady_s},
        distill_loss=distill_loss,
        state=state, out=out, metrics=fleet_metrics, learned=learned)
