"""Episode runner: ONE jit'd lax.scan behind the observation-provider seam.

The fleet episode is a single scan body (`_episode`) parameterized by an
`ObservationProvider` (repro.fleet.api): the provider owns a scan-carry
(`init_carry`), per-step scanned inputs (`scan_xs`), and an `observe`
hook that turns (carry, state, xs) into the `FleetObs` the controller
step consumes. Three providers ship in the registry:

  * `EpisodeTables` (`tables`) — the host-materialized path
    (`build_episode_tables`: O(E*N*Z*P) numpy loops over the procedural
    scene + teacher models, identical inputs to what run_madeye feeds
    MadEyeController). Kept for decision-parity tests against the numpy
    controller and for replaying recorded substrates; every camera
    shares one world and episode length is bounded by host
    materialization.

  * `SceneProvider` (`scene`) — the device-resident path: per-camera
    scenes (repro.scene_jax) advance and are observed *inside* the
    scanned step, so a 512-camera episode with per-camera scene configs
    and per-camera network traces runs with no per-step host transfers,
    and episode length / fleet heterogeneity are free of host work.
    Scene randomness is driven by the per-camera keys threaded through
    `FleetState.rng` (fold_in(camera_key, frame)), so streams are
    reproducible and independent of fleet size or shard layout.

  * `DetectorProvider` (`detector`) — the scene path with the
    approximation model in the loop (paper §3.4): candidate (cell,
    zoom) crops are *rendered* from the scene and *scored* by the
    detector network (models/detector via serving.engine) inside the
    scanned step; the controller ranks on those detections, the oracle
    teachers only grade what it chose (acc_true). The default pipeline
    is candidate-sparse and fused: a search-coupled shortlist keeps the
    `shortlist_k` windows reachable by the shape search / top-EWMA
    cells, kernels/crop_patchify rasterizes the survivors directly into
    ViT patch embeddings, and one batched forward over the flattened
    [F*K] axis scores them (shortlist_k = N*Z is exhaustive and
    bit-identical to the retained fused=False chunked reference).
    Detector params ride in the scan carry so a future in-scan
    distillation step can update them; render noise keys fold from the
    same per-camera keys as the scene, so decisions stay
    fleet-size/shard independent.

Each provider registers as a jax pytree whose static configuration
(SceneSpec, stride, DetectorConfig, chunk) is aux_data — so the one
jitted `_episode` keys its compilation cache on provider statics
automatically, and provider arrays trace like any other argument.

The fleet axis shards over a mesh `data` axis (launch/mesh.py) via each
provider's `shard` hook: shared EpisodeTables are replicated (a few
hundred KB), scene state/params shard with the fleet, detector params
are fleet-shared and replicate.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ewma
from repro.core.rank import Workload
from repro.core.tradeoff import BudgetConfig
from repro.fleet.state import (
    FleetConfig,
    FleetState,
    FleetStatics,
    WorkloadSpec,
    fleet_statics,
    workload_spec,
)
from repro.fleet.step import FleetObs, FleetStepOut, fleet_step
from repro.scene_jax.observe import (
    TeacherArrays,
    detections_obs,
    grid_windows,
    observe_all_cells,
    teacher_arrays,
)
from repro.scene_jax.render import render_fleet_crops, render_noise
from repro.scene_jax.scene import (
    SceneFleetParams,
    SceneSpec,
    SceneState,
    advance_scene,
    init_scene,
    kind_mask,
    scene_fleet_params,
)

# FleetObs fields recorded by collect_obs (everything but the network
# leaves, which the provider carries separately as [E]/[E, F] traces)
_TABLE_FIELDS = ("counts", "areas", "centroid", "spread", "extent",
                 "nbox", "acc_true")


def shard_fleet(state, mesh):
    """Place the fleet axis of every pytree leaf on the mesh `data` axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def sh(x):
        spec = P(*(("data",) + (None,) * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(sh, state)


class EpisodeTables(NamedTuple):
    """Scanned observation substrate; every leaf leads with [E] steps.
    mbps/rtt are [E] for a fleet-shared link or [E, F] per camera."""
    counts: jnp.ndarray     # [E, N, Z, P]
    areas: jnp.ndarray      # [E, N, Z, P]
    centroid: jnp.ndarray   # [E, N, Z, 2]
    spread: jnp.ndarray     # [E, N, Z]
    extent: jnp.ndarray     # [E, N, Z]
    nbox: jnp.ndarray       # [E, N, Z]
    acc_true: jnp.ndarray   # [E, N, Z]
    mbps: jnp.ndarray       # [E] or [E, F]
    rtt: jnp.ndarray        # [E] or [E, F]

    @property
    def n_steps(self) -> int:
        return self.counts.shape[0]

    # -- ObservationProvider hooks (repro.fleet.api) --------------------
    def init_carry(self, state: FleetState):
        return ()

    def scan_xs(self):
        return self

    def observe(self, cfg: FleetConfig, wl: WorkloadSpec, carry,
                state: FleetState, xs):
        return carry, FleetObs(**xs._asdict())

    def shard(self, mesh):
        # fleet-shared tables replicate (a few hundred KB)
        return self


@dataclass(frozen=True)
class SceneProvider:
    """Scene-backed observation provider: everything the scanned step
    needs to generate FleetObs on device. Build with `make_scene_provider`
    (which also returns the matching FleetState so the scene keys in
    `FleetState.rng` line up with the per-camera scene seeds)."""
    spec: SceneSpec             # static scene layout (jit constant)
    params: SceneFleetParams    # per-camera arrays [F, ...]
    teach: TeacherArrays        # per-pair teacher constants
    state0: SceneState          # initial object state [F, M, ...]
    windows: jnp.ndarray        # [N * Z, 4] flattened FOV windows
    mbps: jnp.ndarray           # [E] or [E, F] network trace
    rtt: jnp.ndarray            # [E] or [E, F]
    stride: int                 # scene frames per controller step

    @property
    def n_steps(self) -> int:
        return self.mbps.shape[0]

    # -- ObservationProvider hooks --------------------------------------
    def init_carry(self, state: FleetState):
        return self.state0

    def scan_xs(self):
        return (self.mbps, self.rtt)

    def observe(self, cfg: FleetConfig, wl: WorkloadSpec, carry,
                state: FleetState, xs):
        mbps_t, rtt_t = xs
        sc = advance_scene(self.spec, self.params, state.rng, carry,
                           state.step_idx, self.stride)
        o = observe_all_cells(self.spec, self.teach, self.params, sc,
                              state.step_idx * self.stride, self.windows,
                              task_id=wl.task_id, pair_idx=wl.pair_idx,
                              n_zoom=len(cfg.zoom_levels),
                              cam_salt=state.rng[:, 0])
        obs = FleetObs(counts=o.counts, areas=o.areas, centroid=o.centroid,
                       spread=o.spread, extent=o.extent, nbox=o.nbox,
                       acc_true=o.acc_true, mbps=mbps_t, rtt=rtt_t)
        return sc, obs

    def shard(self, mesh):
        return dataclasses.replace(
            self, state0=shard_fleet(self.state0, mesh),
            params=shard_fleet(self.params, mesh))


def shortlist_windows(cfg: FleetConfig, state: FleetState,
                      neighbor8: jnp.ndarray, k: int) -> jnp.ndarray:
    """Search-coupled candidate shortlist: the [F, K] flattened window
    ids (cell * Z + zoom) worth rendering + scoring this step.

    The shape search only ever explores cells reachable from the
    camera's current state (paper §3.3): the carried shape itself, its
    8-neighbor ring (evolve/resize grow into it), and the top-EWMA cells
    (reseed and scout jump there). Cells are ranked by exactly that
    reachability — shape > ring > normalized EWMA label, with the scout
    rule's sqrt-staleness bonus as the tiebreak — and the top K/Z cells
    contribute all Z zoom windows each. Pure per-camera function of
    (state, grid statics): deterministic, fleet-size/shard independent
    (the same key discipline as the scene streams). lax.top_k breaks
    ties toward the lower cell id, so the selection is stable.
    """
    z = len(cfg.zoom_levels)
    if k <= 0 or k % z != 0:
        raise ValueError(f"shortlist k={k} must be a positive multiple "
                         f"of the {z} zoom levels (whole cells)")
    kc = k // z
    labels = ewma.labels(state.ewma, delta_weight=cfg.delta_weight)
    lnorm = labels / jnp.maximum(
        jnp.max(labels, axis=-1, keepdims=True), 1e-9)
    stale = jnp.sqrt(jnp.maximum(
        (state.step_idx[:, None] - state.last_visit).astype(jnp.float32),
        0.0))
    shape = state.shape
    ring = (shape.astype(jnp.float32) @ neighbor8.astype(jnp.float32)) > 0
    score = (4.0 * shape + 2.0 * (ring & ~shape)
             + lnorm + 1e-3 * stale)
    _, cells = jax.lax.top_k(score, kc)                     # [F, Kc]
    return (cells[:, :, None] * z
            + jnp.arange(z, dtype=cells.dtype)[None, None, :]
            ).reshape(cells.shape[0], kc * z)


@dataclass(frozen=True)
class DetectorProvider:
    """Scene-backed provider with the approximation model in the loop:
    candidate-orientation crops are rendered and scored by the detector
    network inside the scanned step. Build with `make_detector_provider`
    (pass a distilled checkpoint — pytree or .npz path — for a trained
    camera).

    Two pipelines share the observation contract:

      * fused=True (default) — the candidate-sparse fast path: a
        search-coupled shortlist keeps the top `shortlist_k` of the N*Z
        windows per camera (shortlist_k = N*Z reproduces exhaustive
        scoring bit-for-bit), kernels/crop_patchify turns the survivors
        straight into patch-embedding tokens (Pallas kernel via
        use_kernel; crops never hit HBM as pixels), and ONE batched
        forward over the flattened [F*K] axis scores them
        (engine.detector_scores_tokens).
      * fused=False — the pre-shortlist reference: every window rendered
        to pixels and scored through a serial per-chunk lax.map. Kept
        exhaustive-only, as the bit-exact anchor the fast path's parity
        tests pin against.

    With `distill` set (a repro.learn.DistillSpec — static, so it keys
    the jit cache like every other config), the provider LEARNS in-scan:
    a LearnState (per-camera trainable params + optimizer state + pair
    ring) joins the carry, the fused forward routes through per-camera
    heads, and after each fleet_step the `learn` hook harvests teacher
    pairs from the SENT crops and takes a cadence-gated optimizer step —
    entirely inside the episode scan. distill=None compiles the exact
    frozen-params program (decisions bit-identical, pinned by
    tests/test_learn.py).
    """
    scene: SceneProvider        # world + teachers (oracle feedback)
    det_cfg: object             # DetectorConfig (hashable, jit-static)
    det_params: object          # detector pytree (scan carry)
    thresh: jnp.ndarray         # [P] per-pair score threshold
    geo_thresh: jnp.ndarray     # [] score floor for zoom geometry
    noise: jnp.ndarray          # [] render noise scale
    nbr8: jnp.ndarray           # [N, N] 8-neighbor mask (shortlist ring)
    chunk: int                  # windows per slab (static; fused=False)
    shortlist_k: int = 0        # windows scored per camera (0 = all)
    fused: bool = True          # fast path vs reference chunk loop
    use_kernel: bool = False    # Pallas crop_patchify vs jnp reference
    kernel_interpret: bool = True
    distill: object = None      # repro.learn.DistillSpec | None (static)

    @property
    def n_steps(self) -> int:
        return self.scene.n_steps

    @property
    def learns(self) -> bool:
        """True when the episode should call the `learn` hook — kept off
        the ObservationProvider protocol (runtime_checkable would demand
        it of every provider); the episode probes via getattr."""
        return self.distill is not None

    def _effective_k(self) -> int:
        c = self.scene.windows.shape[0]
        k = self.shortlist_k
        return k if 0 < k < c else c

    # -- ObservationProvider hooks --------------------------------------
    def init_carry(self, state: FleetState):
        # detector params ride in the carry; with distill on, the
        # LearnState (per-camera trainable heads/params + opt + ring)
        # rides alongside and is what the optimizer step rewrites
        if self.distill is None:
            return (self.scene.state0, self.det_params)
        from repro.learn.loop import init_learn

        lc = init_learn(self.distill, self.det_cfg, self.det_params,
                        state.step_idx.shape[0], self._effective_k())
        return (self.scene.state0, self.det_params, lc)

    def scan_xs(self):
        return (self.scene.mbps, self.scene.rtt)

    def observe(self, cfg: FleetConfig, wl: WorkloadSpec, carry,
                state: FleetState, xs):
        learn_on = self.distill is not None
        if learn_on:
            sc, dp, lc = carry
        else:
            sc, dp = carry
        mbps_t, rtt_t = xs
        p = self.scene
        kinds = jnp.asarray(kind_mask(p.spec))
        pair_cls = jnp.asarray(wl.pair_cls, jnp.int32)
        res = self.det_cfg.img_res

        sc = advance_scene(p.spec, p.params, state.rng, sc,
                           state.step_idx, p.stride)
        frame = state.step_idx * p.stride
        # oracle pass: only acc_true survives DCE — the teachers grade
        # the camera's choices, they no longer feed its ranking
        o = observe_all_cells(p.spec, p.teach, p.params, sc, frame,
                              p.windows, task_id=wl.task_id,
                              pair_idx=wl.pair_idx,
                              n_zoom=len(cfg.zoom_levels),
                              cam_salt=state.rng[:, 0])
        noise_img = render_noise(state.rng, frame, res) * self.noise

        if learn_on:
            dets, lc = self._score_learn(cfg, state, sc, dp, lc, kinds,
                                         noise_img)
        elif self.fused:
            dets = self._score_fused(cfg, state, sc, dp, kinds, noise_img)
        else:
            dets = self._score_chunked(sc, dp, kinds, noise_img, p, res)
        do = detections_obs(dets, p.windows, pair_cls, self.thresh,
                            self.geo_thresh, o.acc_true,
                            n_zoom=len(cfg.zoom_levels))
        obs = FleetObs(counts=do.counts, areas=do.areas,
                       centroid=do.centroid, spread=do.spread,
                       extent=do.extent, nbox=do.nbox,
                       acc_true=do.acc_true, mbps=mbps_t, rtt=rtt_t)
        return ((sc, dp, lc) if learn_on else (sc, dp)), obs

    def _score_fused(self, cfg, state, sc, dp, kinds, noise_img):
        """Shortlist -> fused crop->token kernel -> one [F*K] forward,
        detections scattered back to the full window axis."""
        from repro.kernels.crop_patchify.ops import crop_patchify
        from repro.serving.engine import detector_scores_tokens

        p = self.scene
        c = p.windows.shape[0]
        k = self.shortlist_k if 0 < self.shortlist_k < c else c
        if k < c:
            widx = shortlist_windows(cfg, state, self.nbr8, k)
            wins = p.windows[widx]                          # [F, K, 4]
        else:
            wins = p.windows                                # shared [C, 4]
        tokens = crop_patchify(
            sc.pos, sc.size, kinds, sc.oid, wins,
            dp["backbone"]["vit"]["patch_embed"],
            patch=self.det_cfg.patch, res=self.det_cfg.img_res,
            min_visible=p.spec.min_visible, noise=noise_img,
            dtype=self.det_cfg.dtype,
            block_k=_auto_chunk(k, self.chunk),
            use_kernel=self.use_kernel,
            interpret=self.kernel_interpret)                # [F, K, gg, D]
        f = tokens.shape[0]
        dets = detector_scores_tokens(
            dp, self.det_cfg,
            tokens.reshape((f * k,) + tokens.shape[2:]))
        dets = jax.tree.map(
            lambda x: x.reshape((f, k) + x.shape[1:]), dets)
        if k < c:
            # un-shortlisted windows read as score-0 detections (empty
            # under any positive threshold), so detections_obs and the
            # step consume the same full [F, C] axis either way
            arange_f = jnp.arange(f)[:, None]
            dets = jax.tree.map(
                lambda x: jnp.zeros((f, c) + x.shape[2:], x.dtype)
                .at[arange_f, widx].set(x), dets)
        return dets

    def _score_chunked(self, sc, dp, kinds, noise_img, p, res):
        """Pre-shortlist reference: serial lax.map over window chunks,
        peak memory [F, chunk, res, res, 3] — the bit-exact anchor."""
        from repro.serving.engine import detector_scores

        c = p.windows.shape[0]
        wchunks = p.windows.reshape(c // self.chunk, self.chunk, 4)

        def score_chunk(wc):
            crops = render_fleet_crops(sc.pos, sc.size, kinds, sc.oid, wc,
                                       res=res,
                                       min_visible=p.spec.min_visible,
                                       noise=noise_img)
            return jax.vmap(
                lambda im: detector_scores(dp, self.det_cfg, im))(crops)

        dets = jax.lax.map(score_chunk, wchunks)
        return jax.tree.map(
            lambda x: jnp.moveaxis(x, 0, 1).reshape(
                (x.shape[1], c) + x.shape[3:]), dets)

    def _score_learn(self, cfg, state, sc, dp, lc, kinds, noise_img):
        """The fused fast path routed through the LEARNED per-camera
        params, staging the student payload for the pair harvest.

        Head-only mode: the shared frozen backbone+neck runs once over
        the flattened [F*K] shortlist (identical compute to the frozen
        path), per-camera head convs finish the forward, and the
        post-neck features are staged — so distillation training re-runs
        ZERO backbone compute. Full-param mode: the whole per-camera
        network scores its own camera's crops (vmap over the fleet) and
        the patch tokens are staged instead."""
        from repro.kernels.crop_patchify.ops import crop_patchify
        from repro.models.detector import (
            detections_from_feats,
            detector_forward_tokens,
            detector_neck_feats_tokens,
        )

        p = self.scene
        c = p.windows.shape[0]
        k = self._effective_k()
        if k < c:
            widx = shortlist_windows(cfg, state, self.nbr8, k)
            wins = p.windows[widx]                          # [F, K, 4]
        else:
            wins = p.windows                                # shared [C, 4]
        tokens = crop_patchify(
            sc.pos, sc.size, kinds, sc.oid, wins,
            dp["backbone"]["vit"]["patch_embed"],
            patch=self.det_cfg.patch, res=self.det_cfg.img_res,
            min_visible=p.spec.min_visible, noise=noise_img,
            dtype=self.det_cfg.dtype,
            block_k=_auto_chunk(k, self.chunk),
            use_kernel=self.use_kernel,
            interpret=self.kernel_interpret)                # [F, K, gg, D]
        f = tokens.shape[0]
        if k == c:
            widx = jnp.broadcast_to(
                jnp.arange(c, dtype=jnp.int32)[None], (f, c))

        if self.distill.head_only:
            feats = detector_neck_feats_tokens(
                dp, self.det_cfg,
                tokens.reshape((f * k,) + tokens.shape[2:]))
            fe = feats.reshape((f, k) + feats.shape[1:])    # [F,K,g,g,Fd]
            dets = jax.vmap(
                lambda heads, x: detections_from_feats(
                    self.det_cfg, heads, x))(lc.params, fe)
            payload = fe
        else:
            dets = jax.vmap(
                lambda par, x: detector_forward_tokens(
                    par, self.det_cfg, x))(lc.params, tokens)
            payload = tokens
        if k < c:
            arange_f = jnp.arange(f)[:, None]
            dets = jax.tree.map(
                lambda x: jnp.zeros((f, c) + x.shape[2:], x.dtype)
                .at[arange_f, widx].set(x), dets)
        lc = lc._replace(staged=payload.astype(lc.staged.dtype),
                         staged_widx=widx.astype(jnp.int32))
        return dets, lc

    def learn(self, cfg: FleetConfig, wl: WorkloadSpec, carry,
              state: FleetState, out):
        """Post-step learning hook (called by _episode when `learns`):
        harvest teacher pairs from the crops the budget SENT, then take
        the cadence-gated optimizer step. `state` is the post-step
        controller state (step_idx already incremented — the observation
        frame is recovered as (step_idx - 1) * stride); `out` this
        step's FleetStepOut. Returns (carry', aux) with aux {"loss": [F]
        (-1.0 for skipped/idle cameras), "lr": [F]} — emitted into the
        scan outputs. Every stage is row-wise per camera, preserving
        fleet-size/shard independence."""
        from repro.learn.loop import distill_step
        from repro.learn.pairs import (
            harvest_into_buffer,
            select_sent_windows,
            teacher_window_targets,
        )

        sc, dp, lc = carry
        p = self.scene
        sel_widx, sel_ok = select_sent_windows(
            out, len(cfg.zoom_levels), self.distill.harvest)
        boxes, classes, bvalid = teacher_window_targets(
            p.spec, p.teach, p.params, sc,
            (state.step_idx - 1) * p.stride, p.windows[sel_widx],
            self.det_cfg.max_boxes, state.rng[:, 0])
        lc = lc._replace(buf=harvest_into_buffer(
            lc.buf, lc.staged, lc.staged_widx, sel_widx, sel_ok,
            boxes, classes, bvalid))
        lc, aux = distill_step(self.distill, self.det_cfg, lc,
                               state.step_idx)
        return (sc, dp, lc), aux

    def learned_params(self, carry, camera=None):
        """Full detector params from a learning episode's final carry —
        per-camera trained subtree merged with the shared frozen rest.
        camera=None keeps the fleet axis on trained leaves; an int
        selects one camera's checkpoint (ready for
        `save_detector_params`)."""
        from repro.learn.loop import merged_params

        if self.distill is None:
            raise ValueError("learned_params needs a distill-enabled "
                             "provider (distill=None runs frozen)")
        sc, dp, lc = carry
        return merged_params(self.distill, dp, lc.params, camera)

    def shard(self, mesh):
        # scene state/params shard with the fleet; detector params are
        # fleet-shared and replicate (as is the nbr8 grid geometry)
        return dataclasses.replace(self, scene=self.scene.shard(mesh))


# static configuration is aux_data: the one jitted episode keys its
# compilation cache on (SceneSpec, stride, DetectorConfig, chunk) through
# the treedef, arrays trace as children
jax.tree_util.register_dataclass(
    SceneProvider,
    data_fields=["params", "teach", "state0", "windows", "mbps", "rtt"],
    meta_fields=["spec", "stride"])
jax.tree_util.register_dataclass(
    DetectorProvider,
    data_fields=["scene", "det_params", "thresh", "geo_thresh", "noise",
                 "nbr8"],
    meta_fields=["det_cfg", "chunk", "shortlist_k", "fused", "use_kernel",
                 "kernel_interpret", "distill"])


def build_episode_tables(video, workload: Workload, tables: dict,
                         budget: BudgetConfig, trace, *,
                         approx_miss: float = 0.12,
                         acc_table: np.ndarray | None = None,
                         max_steps: int | None = None) -> EpisodeTables:
    """Materialize what `observe` + the backend would return at every
    (controller timestep, cell, zoom) — the exact observations
    serving/pipeline.run_madeye feeds the numpy controller."""
    from repro.serving import accuracy as acc_mod
    from repro.serving.pipeline import ZOOM_LEVELS, _observation_from_tables

    grid = video.grid
    spec = workload_spec(workload)
    n, z_n, p_n = grid.n_cells, len(ZOOM_LEVELS), len(spec.pairs)
    if acc_table is None:
        acc_table = acc_mod.workload_acc_table(video, workload, tables,
                                               ZOOM_LEVELS)
    stride = max(1, int(round(video.fps / budget.fps)))
    frames = list(range(0, video.n_frames, stride))
    if max_steps is not None:
        frames = frames[:max_steps]
    e = len(frames)

    counts = np.zeros((e, n, z_n, p_n), np.float32)
    areas = np.zeros((e, n, z_n, p_n), np.float32)
    centroid = np.zeros((e, n, z_n, 2), np.float32)
    spread = np.zeros((e, n, z_n), np.float32)
    extent = np.zeros((e, n, z_n), np.float32)
    nbox = np.zeros((e, n, z_n), np.int32)
    acc_true = np.zeros((e, n, z_n), np.float32)
    mbps = np.zeros(e, np.float32)

    for ei, t in enumerate(frames):
        acc_true[ei] = acc_table[t]
        mbps[ei] = trace.observed_mbps(t)
        for c in range(n):
            for zi in range(z_n):
                o = _observation_from_tables(tables, workload, grid, t, c,
                                             zi, approx_miss)
                for pi, pair in enumerate(spec.pairs):
                    counts[ei, c, zi, pi] = o.counts.get(pair, 0)
                    areas[ei, c, zi, pi] = o.areas.get(pair, 0.0)
                k = o.box_centers.shape[0]
                nbox[ei, c, zi] = k
                if k:
                    centroid[ei, c, zi] = o.centroid
                    spread[ei, c, zi] = float(np.linalg.norm(
                        o.box_centers - o.centroid, axis=1).mean())
                    extent[ei, c, zi] = float(o.box_sizes.max())

    return EpisodeTables(
        counts=jnp.asarray(counts), areas=jnp.asarray(areas),
        centroid=jnp.asarray(centroid), spread=jnp.asarray(spread),
        extent=jnp.asarray(extent), nbox=jnp.asarray(nbox),
        acc_true=jnp.asarray(acc_true), mbps=jnp.asarray(mbps),
        rtt=jnp.full(e, float(trace.rtt_s), np.float32))


# ---------------------------------------------------------------------------
# provider construction (the registry factories — repro.fleet.api)
# ---------------------------------------------------------------------------

def budget_from_config(cfg: FleetConfig) -> BudgetConfig:
    """Recover the numpy-side BudgetConfig a FleetConfig mirrors, so
    host-materialization helpers and the jitted step consume identical
    constants (the inverse of `fleet_config` for the budget fields)."""
    return BudgetConfig(
        fps=cfg.fps, rotation_speed=cfg.rotation_speed,
        hop_degrees=cfg.hop_degrees, approx_infer_s=cfg.approx_infer_s,
        backend_infer_s=cfg.backend_infer_s, frame_bytes=cfg.frame_bytes,
        min_send=cfg.min_send, max_send=cfg.max_send,
        pipelined=cfg.pipelined)


def make_tables_provider(grid, workload: Workload, cfg: FleetConfig, *,
                         n_cameras: int, n_steps: int | None = None,
                         seed: int = 3, mbps: float = 24.0,
                         rtt_ms: float = 20.0, approx_miss: float = 0.12,
                         scene_fps: float = 15.0, video=None, tables=None,
                         trace=None, acc_table=None
                         ) -> tuple[EpisodeTables, FleetState]:
    """Host-materialized provider: numpy scene + teacher oracles recorded
    into EpisodeTables (every camera shares one world).

    Builds the substrate from `seed` (procedural scene at `scene_fps`,
    long enough for `n_steps` controller steps at cfg.fps, fixed
    mbps/rtt link) — or reuses prebuilt `video`/`tables`/`trace`/
    `acc_table` objects when the caller already has them (the serving
    launcher and benchmarks do; those kwargs are in-memory-only, not
    JSON-serializable)."""
    from repro.data import SceneConfig, build_video
    from repro.fleet.state import init_fleet
    from repro.serving import NetworkTrace, detection_tables

    budget = budget_from_config(cfg)
    if video is None:
        if n_steps is None:
            raise ValueError("tables provider needs n_steps (or a "
                             "prebuilt video=) to size the substrate")
        stride = max(1, int(round(scene_fps / cfg.fps)))
        video = build_video(grid, SceneConfig(fps=scene_fps, seed=seed),
                            (n_steps * stride + 2) / scene_fps)
    if tables is None:
        tables = detection_tables(video, workload)
    if trace is None:
        trace = NetworkTrace.fixed(mbps, rtt_ms, video.n_frames)
    ep = build_episode_tables(video, workload, tables, budget, trace,
                              approx_miss=approx_miss, acc_table=acc_table,
                              max_steps=n_steps)
    return ep, init_fleet(grid, n_cameras)


def fleet_network_traces(n_steps: int, n_cameras: int | None = None, *,
                         mbps=24.0, rtt_ms=20.0, seed: int | None = None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-episode network arrays for the scanned step.

    With n_cameras=None returns fleet-shared [E] traces; otherwise
    [E, F] with `mbps`/`rtt_ms` broadcast per camera. seed=None gives
    fixed links; an int seed gives every camera its own LTE-ish AR(1)
    trace with deep fades (transport.ar1_mobile_trace — the same model
    NetworkTrace.mobile draws from).
    """
    from repro.serving.transport import ar1_mobile_trace

    shape = (n_steps,) if n_cameras is None else (n_steps, n_cameras)
    base = np.broadcast_to(np.asarray(mbps, np.float32), shape[1:])
    rtt = np.broadcast_to(np.asarray(rtt_ms, np.float32), shape[1:]) / 1e3
    if seed is None:
        x = np.broadcast_to(base, shape).astype(np.float32)
    else:
        x = ar1_mobile_trace(n_steps, base,
                             np.random.default_rng(seed)).astype(np.float32)
    return (jnp.asarray(x),
            jnp.asarray(np.broadcast_to(rtt, shape).astype(np.float32)))


def make_scene_provider(grid, workload: Workload, cfg: FleetConfig, *,
                        n_cameras: int, n_steps: int,
                        spec: SceneSpec | None = None, seed: int = 0,
                        scene_seeds=None, person_speed=1.2, car_speed=10.0,
                        churn=0.01, n_people=None, n_cars=None,
                        mbps=24.0, rtt_ms=20.0, net_seed: int | None = None,
                        seed_size: int = 6
                        ) -> tuple[SceneProvider, FleetState]:
    """Build a heterogeneous scene-backed provider + the matching fleet
    state. Scalar scene arguments broadcast; pass [F] arrays for
    per-camera heterogeneity (density via n_people/n_cars, dynamics via
    speeds/churn, world layout via scene_seeds). The returned FleetState
    carries fold_in(PRNGKey(seed), scene_seeds[f]) in `rng` — the same
    keys the provider's initial scene state was drawn from."""
    from repro.fleet.state import init_fleet

    spec = spec or SceneSpec()
    params, rng = scene_fleet_params(
        spec, n_cameras, seed=seed, scene_seeds=scene_seeds,
        person_speed=person_speed, car_speed=car_speed, churn=churn,
        n_people=n_people, n_cars=n_cars)
    state0 = init_scene(spec, params, rng)
    sw = workload_spec(workload)
    net_mbps, net_rtt = fleet_network_traces(
        n_steps, None if np.isscalar(mbps) and np.isscalar(rtt_ms)
        and net_seed is None else n_cameras,
        mbps=mbps, rtt_ms=rtt_ms, seed=net_seed)
    provider = SceneProvider(
        spec=spec, params=params, teach=teacher_arrays(sw.pairs),
        state0=state0, windows=grid_windows(grid, cfg.zoom_levels),
        mbps=net_mbps, rtt=net_rtt,
        stride=max(1, int(round(spec.fps / cfg.fps))))
    # install the SAME key array the initial scene state was drawn from —
    # one derivation, so init stream and step stream can't drift apart
    state = init_fleet(grid, n_cameras, seed_size, rng=rng)
    return provider, state


def save_detector_params(path: str, params) -> str:
    """Write a detector params pytree (nested dicts of arrays) to .npz,
    keys '/'-joined — the checkpoint format `make_detector_provider`
    loads. Anything outside that contract (non-dict interior nodes,
    '/'-bearing or empty keys, non-array leaves) fails loudly here
    rather than producing an .npz that loads into a different treedef.
    Returns the path written."""
    flat = {}

    def walk(tree, prefix):
        if isinstance(tree, dict):
            for k in sorted(tree):
                k = str(k)
                if "/" in k or not k:
                    raise ValueError(
                        f"key {k!r} under {prefix or '<root>'!r} would "
                        f"not round-trip through '/'-joined npz names")
                walk(tree[k], f"{prefix}/{k}" if prefix else k)
        elif not prefix:
            raise TypeError("detector params must be a dict pytree, got "
                            f"{type(tree).__name__}")
        elif not hasattr(tree, "shape"):
            raise TypeError(f"leaf {prefix!r} is {type(tree).__name__}, "
                            f"not an array")
        else:
            flat[prefix] = np.asarray(tree)

    walk(params, "")
    np.savez(path, **flat)
    return path


def load_detector_params(path: str) -> dict:
    """Load a `save_detector_params` .npz back into the nested pytree."""
    out: dict = {}
    with np.load(path) as z:
        for key in z.files:
            node = out
            parts = key.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(z[key])
    return out


def _auto_chunk(n_windows: int, default: int) -> int:
    """Largest divisor of n_windows that is <= default (>= 1). The
    auto-selected render+infer slab for the chunked reference path: on
    grids where the one-cell-row default does not divide N*Z, walk down
    to the nearest divisor instead of silently slabbing unevenly."""
    chunk = max(1, min(default, n_windows))
    while n_windows % chunk != 0:
        chunk -= 1
    return chunk


def make_detector_provider(grid, workload: Workload, cfg: FleetConfig, *,
                           n_cameras: int, n_steps: int,
                           det_cfg=None, det_params=None,
                           det_seed: int = 0, thresh=None,
                           geo_thresh: float | None = None,
                           noise: float = 0.05,
                           chunk: int | None = None,
                           shortlist_k: int | None = None,
                           fused: bool = True,
                           use_kernel: bool = False,
                           kernel_interpret: bool = True,
                           distill=None, **scene_kwargs
                           ) -> tuple[DetectorProvider, FleetState]:
    """Scene provider + the approximation detector scored in-step.

    det_cfg defaults to the madeye-approx smoke config (64 px crops — the
    crop resolution IS det_cfg.img_res). det_params select the camera's
    approximation model: a trained pytree, a `.npz` checkpoint path
    (written by `save_detector_params`, e.g. a distilled snapshot), or
    None for a fresh undistilled `detector_init(PRNGKey(det_seed))` demo
    net. `thresh` broadcasts to a per-pair [P] score threshold; left
    None it adapts to the params source — 0.3 for the undistilled demo
    (inside a fresh net's score range, so counts stay scene-dependent),
    0.5 for a trained checkpoint — and `geo_thresh` (zoom-geometry score
    floor) follows the same rule at +0.05.

    Fast-path knobs: `shortlist_k` caps how many of the N*Z candidate
    windows are rendered + scored per camera per step (the
    search-coupled shortlist — must be a multiple of the zoom count;
    None/N*Z scores everything, reproducing exhaustive behavior
    bit-for-bit); `fused` picks the candidate-sparse fused pipeline
    (default) vs the pre-shortlist chunked reference; `use_kernel` /
    `kernel_interpret` dispatch the fused crop->token stage to the
    Pallas crop_patchify kernel (TPU) instead of the jnp reference.
    `chunk` bounds the reference path's render+infer slab (must divide
    N*Z, default one cell-row of zooms at a time — `_auto_chunk`).
    `scene_kwargs` are make_scene_provider's heterogeneity knobs.

    `distill` turns on in-scan continual distillation (paper §3.4): a
    repro.learn.DistillSpec, a dict of its fields, or True for the
    default spec — the camera's per-query heads then train inside the
    episode scan on teacher grades of the crops the budget sent. Fused
    pipeline only (the chunked reference stays the frozen bit-exact
    anchor); None keeps today's frozen-params program exactly.
    """
    from repro.configs import get_smoke_config
    from repro.models.detector import detector_init

    if det_cfg is None:
        det_cfg = get_smoke_config("madeye-approx")
    trained = det_params is not None
    if isinstance(det_params, (str, bytes)):
        det_params = load_detector_params(det_params)
    elif det_params is None:
        det_params = detector_init(jax.random.PRNGKey(det_seed), det_cfg)
    if thresh is None:
        thresh = 0.5 if trained else 0.3
    if geo_thresh is None:
        geo_thresh = float(np.asarray(thresh).max()) + 0.05
    scene, state = make_scene_provider(
        grid, workload, cfg, n_cameras=n_cameras, n_steps=n_steps,
        **scene_kwargs)
    n_pairs = len(workload_spec(workload).pairs)
    c = scene.windows.shape[0]
    z = len(cfg.zoom_levels)
    if chunk is None:
        chunk = _auto_chunk(c, z * max(1, cfg.n_pan))
    elif c % chunk != 0:
        raise ValueError(
            f"chunk={chunk} must divide the {c} candidate windows "
            f"(n_cells * n_zoom) — a non-dividing slab would silently "
            f"fall back to rendering all windows at once")
    if shortlist_k is None:
        shortlist_k = c
    elif not (0 < shortlist_k <= c) or shortlist_k % z != 0:
        raise ValueError(
            f"shortlist_k={shortlist_k} must be a multiple of the "
            f"{z} zoom levels in [{z}, {c}] — the shortlist keeps whole "
            f"cells (all zooms of a kept cell are scored)")
    if not fused and shortlist_k < c:
        raise ValueError(
            "the chunked reference path (fused=False) is exhaustive-"
            f"only; drop shortlist_k={shortlist_k} or use the fused "
            "fast path")
    if shortlist_k < c and (float(np.min(np.asarray(thresh))) <= 0.0
                            or float(geo_thresh) <= 0.0):
        raise ValueError(
            "shortlisting needs strictly positive thresh/geo_thresh: "
            "un-shortlisted windows are scattered as score-0 "
            "detections, which only read as empty under a positive "
            f"threshold (got thresh={thresh!r}, "
            f"geo_thresh={geo_thresh!r})")
    from repro.learn.spec import normalize_distill

    distill = normalize_distill(distill)
    if distill is not None:
        if not fused:
            raise ValueError(
                "in-scan distillation rides the fused fast path (the "
                "student payload is staged from the fused forward); the "
                "chunked reference (fused=False) stays the frozen "
                "bit-exact anchor — drop distill or fused=False")
        if distill.harvest > grid.n_cells:
            raise ValueError(
                f"distill.harvest={distill.harvest} exceeds the "
                f"{grid.n_cells} grid cells — no step can send that "
                f"many distinct orientations")
    provider = DetectorProvider(
        scene=scene, det_cfg=det_cfg, det_params=det_params,
        thresh=jnp.broadcast_to(
            jnp.asarray(thresh, jnp.float32), (n_pairs,)),
        geo_thresh=jnp.asarray(geo_thresh, jnp.float32),
        noise=jnp.asarray(noise, jnp.float32),
        nbr8=fleet_statics(grid).neighbor8,
        chunk=chunk, shortlist_k=shortlist_k, fused=fused,
        use_kernel=use_kernel, kernel_interpret=kernel_interpret,
        distill=distill)
    return provider, state


# ---------------------------------------------------------------------------
# THE episode: one scan body for every provider
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "wl", "collect_obs", "metrics"))
def _episode(cfg: FleetConfig, wl: WorkloadSpec, statics: FleetStatics,
             state: FleetState, provider, *, collect_obs: bool = False,
             metrics=None):
    """The unified scan body: provider.observe generates this step's
    FleetObs from (provider carry, controller state, scanned xs), then
    fleet_step consumes it. Every provider — host tables, device scenes,
    detector-in-the-loop — runs through this one program; adding a
    scenario means adding a provider, not a fourth scan body.

    collect_obs additionally records camera 0's observation tables
    (per-camera [F, ...] leaves sliced to [0]) so a scene episode can be
    re-materialized as EpisodeTables — see materialize_scene_tables.

    metrics (a static repro.obs.MetricsSpec, part of the jit cache key)
    additionally emits a per-step FleetMetrics dict from *inside* the
    scan (shortlist hit-rate, chosen-vs-oracle rank, EWMA labels, budget
    counters — repro.obs.metrics.step_metrics); when None/disabled this
    function compiles to the exact metrics-free program, so decisions
    are bit-identical either way (pinned by tests/test_obs.py).

    Learning providers (getattr(provider, "learns", False) — the
    DetectorProvider with a DistillSpec) additionally get their `learn`
    hook called after every fleet_step, the per-step learn aux joins the
    extras dict under "learn" (and, with metrics on, as
    distill_loss/distill_lr in the FleetMetrics), and the FINAL provider
    carry is returned as a third element — the learned params live
    there. distill off compiles the exact pre-learning program.

    With any extra enabled, ys becomes (FleetStepOut, extras dict keyed
    "obs"/"metrics"/"learn"); bare FleetStepOut otherwise.
    """
    if metrics is not None and not metrics.enabled:
        metrics = None
    learns = getattr(provider, "learns", False)

    def body(carry, xs):
        st, pc = carry
        pc, obs = provider.observe(cfg, wl, pc, st, xs)
        st2, out = fleet_step(cfg, wl, statics, st, obs)
        if learns:
            pc, laux = provider.learn(cfg, wl, pc, st2, out)
        if collect_obs or metrics is not None or learns:
            ex = {}
            if collect_obs:
                ex["obs"] = {f: getattr(obs, f)[0] for f in _TABLE_FIELDS}
            if metrics is not None:
                from repro.obs.metrics import step_metrics

                ex["metrics"] = step_metrics(metrics, cfg, provider,
                                             st, st2, obs, out)
            if learns:
                ex["learn"] = laux
                if metrics is not None:
                    # distill keys join the emitted FleetMetrics only on
                    # learning runs — MetricsSpec.keys() (and the
                    # metrics-off parity pin) stay distill-agnostic
                    ex["metrics"]["distill_loss"] = laux["loss"]
                    ex["metrics"]["distill_lr"] = laux["lr"]
            return (st2, pc), (out, ex)
        return (st2, pc), out

    (state, pc_final), ys = jax.lax.scan(
        body, (state, provider.init_carry(state)), provider.scan_xs())
    if learns:
        return state, ys, pc_final
    return state, ys


def materialize_scene_tables(cfg: FleetConfig, wl: WorkloadSpec,
                             statics: FleetStatics, state: FleetState,
                             provider: SceneProvider) -> EpisodeTables:
    """Host-materialize the observation stream camera 0 of `provider`
    would see — an EpisodeTables the tables-backed path can scan.

    Deliberately runs the identical full-fleet scene episode program
    (not an F=1 slice): the recorded floats are then bit-identical to
    what the in-scan provider feeds fleet_step, which is what the
    decision-parity tests pin — a differently-shaped program could
    legally round reductions differently. That costs one episode at full
    F; for cheap replay tables where bit-exactness doesn't matter, build
    the provider/state at n_cameras=1 and materialize that instead."""
    _, (out, ex) = _episode(cfg, wl, statics, state, provider,
                            collect_obs=True)
    rec = ex["obs"]
    mbps, rtt = provider.mbps, provider.rtt
    if mbps.ndim == 2:
        mbps = mbps[:, 0]
    if rtt.ndim == 2:
        rtt = rtt[:, 0]
    return EpisodeTables(mbps=mbps, rtt=rtt,
                         **{f: rec[f] for f in _TABLE_FIELDS})


def run_fleet_episode(cfg: FleetConfig, wl: WorkloadSpec,
                      statics: FleetStatics, state: FleetState,
                      provider, *, mesh=None, metrics=None):
    """Run the whole episode in one jit'd scan.

    `provider` is any ObservationProvider — the shipped EpisodeTables /
    SceneProvider / DetectorProvider, or anything registered through
    repro.fleet.api. Returns (final state, FleetStepOut with leaves
    stacked to [E, F, ...]). With `mesh`, the fleet axis (controller
    state plus whatever the provider's `shard` hook places — scene
    state/params on the scene paths) is sharded over the mesh `data`
    axis first, and the scan runs SPMD across devices, like
    launch/serve.py's batched inference path.

    `metrics` (a repro.obs.MetricsSpec) turns on in-scan telemetry; the
    return becomes (final state, FleetStepOut, FleetMetrics dict with
    leaves [E, ...]). With it None/disabled the compiled program is the
    exact metrics-free one and the return stays a 2-tuple.

    A LEARNING provider (DetectorProvider with distill set) appends two
    more elements: (..., extras, final_carry) where extras is the
    per-step dict {"learn": {...}} (+ "metrics" when enabled — also
    reachable positionally as the 3-tuple's metrics element) and
    final_carry holds the learned params
    (provider.learned_params(final_carry)).

    Prefer `repro.fleet.api.run_fleet(spec)` unless you are composing
    providers/state yourself (parity tests and benchmarks do).
    """
    if mesh is not None:
        state = shard_fleet(state, mesh)
        provider = provider.shard(mesh)
    if metrics is not None and not metrics.enabled:
        metrics = None
    learns = getattr(provider, "learns", False)
    if learns:
        state, (out, ex), fc = _episode(cfg, wl, statics, state, provider,
                                        metrics=metrics)
        if metrics is None:
            return state, out, ex, fc
        return state, out, ex["metrics"], ex, fc
    if metrics is None:
        return _episode(cfg, wl, statics, state, provider)
    state, (out, ex) = _episode(cfg, wl, statics, state, provider,
                                metrics=metrics)
    return state, out, ex["metrics"]
