"""Episode runner: lax.scan over precomputed scene tables.

The procedural scene (data/scene.py) is numpy and stateful, so the runner
splits the episode the same way the serving pipeline does: the observation
substrate — approx-model counts/areas/box geometry for every (frame, cell,
zoom) plus the oracle accuracy table and network trace — is materialized
once on the host (`build_episode_tables`, identical inputs to what
run_madeye feeds MadEyeController), then the whole fleet episode runs as
ONE jit'd lax.scan over those tables. The fleet axis shards over a mesh
`data` axis (launch/mesh.py) via `shard_fleet`; the scanned tables are
replicated (they are a few hundred KB).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rank import Workload
from repro.core.tradeoff import BudgetConfig
from repro.fleet.state import (
    FleetConfig,
    FleetState,
    FleetStatics,
    WorkloadSpec,
    workload_spec,
)
from repro.fleet.step import FleetObs, FleetStepOut, fleet_step


class EpisodeTables(NamedTuple):
    """Scanned observation substrate; every leaf leads with [E] steps."""
    counts: jnp.ndarray     # [E, N, Z, P]
    areas: jnp.ndarray      # [E, N, Z, P]
    centroid: jnp.ndarray   # [E, N, Z, 2]
    spread: jnp.ndarray     # [E, N, Z]
    extent: jnp.ndarray     # [E, N, Z]
    nbox: jnp.ndarray       # [E, N, Z]
    acc_true: jnp.ndarray   # [E, N, Z]
    mbps: jnp.ndarray       # [E]
    rtt: jnp.ndarray        # [E]

    @property
    def n_steps(self) -> int:
        return self.counts.shape[0]


def build_episode_tables(video, workload: Workload, tables: dict,
                         budget: BudgetConfig, trace, *,
                         approx_miss: float = 0.12,
                         acc_table: np.ndarray | None = None,
                         max_steps: int | None = None) -> EpisodeTables:
    """Materialize what `observe` + the backend would return at every
    (controller timestep, cell, zoom) — the exact observations
    serving/pipeline.run_madeye feeds the numpy controller."""
    from repro.serving import accuracy as acc_mod
    from repro.serving.pipeline import ZOOM_LEVELS, _observation_from_tables

    grid = video.grid
    spec = workload_spec(workload)
    n, z_n, p_n = grid.n_cells, len(ZOOM_LEVELS), len(spec.pairs)
    if acc_table is None:
        acc_table = acc_mod.workload_acc_table(video, workload, tables,
                                               ZOOM_LEVELS)
    stride = max(1, int(round(video.fps / budget.fps)))
    frames = list(range(0, video.n_frames, stride))
    if max_steps is not None:
        frames = frames[:max_steps]
    e = len(frames)

    counts = np.zeros((e, n, z_n, p_n), np.float32)
    areas = np.zeros((e, n, z_n, p_n), np.float32)
    centroid = np.zeros((e, n, z_n, 2), np.float32)
    spread = np.zeros((e, n, z_n), np.float32)
    extent = np.zeros((e, n, z_n), np.float32)
    nbox = np.zeros((e, n, z_n), np.int32)
    acc_true = np.zeros((e, n, z_n), np.float32)
    mbps = np.zeros(e, np.float32)

    for ei, t in enumerate(frames):
        acc_true[ei] = acc_table[t]
        mbps[ei] = trace.observed_mbps(t)
        for c in range(n):
            for zi in range(z_n):
                o = _observation_from_tables(tables, workload, grid, t, c,
                                             zi, approx_miss)
                for pi, pair in enumerate(spec.pairs):
                    counts[ei, c, zi, pi] = o.counts.get(pair, 0)
                    areas[ei, c, zi, pi] = o.areas.get(pair, 0.0)
                k = o.box_centers.shape[0]
                nbox[ei, c, zi] = k
                if k:
                    centroid[ei, c, zi] = o.centroid
                    spread[ei, c, zi] = float(np.linalg.norm(
                        o.box_centers - o.centroid, axis=1).mean())
                    extent[ei, c, zi] = float(o.box_sizes.max())

    return EpisodeTables(
        counts=jnp.asarray(counts), areas=jnp.asarray(areas),
        centroid=jnp.asarray(centroid), spread=jnp.asarray(spread),
        extent=jnp.asarray(extent), nbox=jnp.asarray(nbox),
        acc_true=jnp.asarray(acc_true), mbps=jnp.asarray(mbps),
        rtt=jnp.full(e, float(trace.rtt_s), np.float32))


def shard_fleet(state: FleetState, mesh) -> FleetState:
    """Place the fleet axis of every state leaf on the mesh `data` axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def sh(x):
        spec = P(*(("data",) + (None,) * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(sh, state)


@partial(jax.jit, static_argnames=("cfg", "wl"))
def _episode(cfg: FleetConfig, wl: WorkloadSpec, statics: FleetStatics,
             state: FleetState, tables: EpisodeTables):
    def body(st, xs):
        # xs is one EpisodeTables step; match FleetObs fields by name
        st, out = fleet_step(cfg, wl, statics, st,
                             FleetObs(**xs._asdict()))
        return st, out

    return jax.lax.scan(body, state, tables)


def run_fleet_episode(cfg: FleetConfig, wl: WorkloadSpec,
                      statics: FleetStatics, state: FleetState,
                      tables: EpisodeTables, *,
                      mesh=None) -> tuple[FleetState, FleetStepOut]:
    """Run the whole episode in one jit'd scan.

    Returns (final state, FleetStepOut with leaves stacked to [E, F, ...]).
    With `mesh`, the fleet axis is sharded over the mesh `data` axis first
    (the scan then runs SPMD across devices, like launch/serve.py's
    batched inference path).
    """
    if mesh is not None:
        state = shard_fleet(state, mesh)
    return _episode(cfg, wl, statics, state, tables)
