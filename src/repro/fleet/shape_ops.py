"""Masked, fixed-shape fleet versions of core/search.py (paper §3.3).

Every function operates on the whole fleet batch at once: masks are
[F, N] bool, per-camera scalars are [F]. The data-dependent while-loops of
the numpy reference become lax.while_loops whose carry updates are masked
per camera (`done` lanes no-op), with static iteration bounds guaranteed
by the algorithm (each live iteration strictly shrinks the head/tail span
or consumes a swap).

Tie-breaking matches the numpy implementation exactly (stable sorts break
toward the lower cell id; argmax/argmin return the first extremum), so a
1-camera fleet reproduces MadEyeController's decisions bit for bit — the
parity test in tests/test_fleet_parity.py asserts it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.fleet.state import FleetConfig, FleetStatics
from repro.kernels.neighbor_score.ops import neighbor_scores

INF = jnp.inf


def _onehot(idx: jnp.ndarray, n: int) -> jnp.ndarray:
    """[F] int -> [F, n] bool."""
    return jax.nn.one_hot(idx, n, dtype=jnp.bool_)


def _scores(cfg: FleetConfig, statics: FleetStatics, mask, has_boxes,
            centroids, head):
    return neighbor_scores(
        mask, has_boxes, centroids, head,
        statics.d_center, statics.overlap,
        statics.centers[:, 0], statics.centers[:, 1], statics.neighbor8,
        use_kernel=cfg.use_kernel, interpret=cfg.kernel_interpret)


# ---------------------------------------------------------------------------
# contiguity (8-connected, batched log-doubling closure)
# ---------------------------------------------------------------------------

def induced_adj(mask: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """[..., N] mask + [N, N] adjacency -> [..., N, N] induced adjacency."""
    return adj & mask[..., None, :] & mask[..., :, None]


def flood_reach(mask: jnp.ndarray, seed: jnp.ndarray,
                adj: jnp.ndarray) -> jnp.ndarray:
    """Cells of `mask` reachable from `seed` (both [..., N] bool).

    `adj` may be the shared [N, N] lattice or a per-batch [..., N, N]
    induced adjacency. One mat-vec hop per iteration, stopping at the
    fixpoint — search shapes have diameter ~4, so the data-dependent
    early exit beats both a fixed N-hop loop and an N^3 closure.
    """
    adj_f = adj.astype(jnp.float32)

    def cond(c):
        return c["changed"]

    def body(c):
        r = c["reach"]
        hop = jnp.einsum("...n,...nm->...m", r.astype(jnp.float32), adj_f)
        grown = mask & (r | (hop > 0))
        return {"reach": grown, "changed": jnp.any(grown != r)}

    out = lax.while_loop(
        cond, body, {"reach": seed & mask, "changed": jnp.asarray(True)})
    return out["reach"]


def is_contiguous(mask: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """[F, N] bool -> [F] bool (empty / singleton masks are contiguous)."""
    n = mask.shape[-1]
    first = jnp.argmax(mask, axis=-1)
    reach = flood_reach(mask, _onehot(first, n), adj)
    return jnp.all(~mask | reach, axis=-1)


def first_removable(mask: jnp.ndarray, labels: jnp.ndarray,
                    adj: jnp.ndarray) -> jnp.ndarray:
    """Lowest-label member whose removal keeps the shape 8-connected,
    falling back to the lowest-label member outright (the numpy shrink
    rule). Returns T [F] int32.

    Candidates are probed in label order with a while_loop — the first
    candidate is almost always a removable leaf, so this costs ~1 single-
    candidate contiguity check instead of testing all N members at once.
    """
    f, n = mask.shape
    ord_asc = jnp.argsort(jnp.where(mask, labels, INF), stable=True)
    m = jnp.sum(mask, axis=-1)

    def cond(c):
        return jnp.any(~c["found"]) & (c["r"] < n)

    def body(c):
        T = ord_asc[jnp.arange(f), jnp.minimum(c["r"], n - 1)]
        ok = (is_contiguous(mask & ~_onehot(T, n), adj)
              & (c["r"] < m))                  # rank must be a member
        newly = ~c["found"] & ok
        return {"pick": jnp.where(newly, T, c["pick"]),
                "found": c["found"] | ok, "r": c["r"] + 1}

    init = {"pick": ord_asc[:, 0].astype(jnp.int32),
            "found": jnp.zeros(f, bool), "r": jnp.zeros((), jnp.int32)}
    return lax.while_loop(cond, body, init)["pick"].astype(jnp.int32)


# ---------------------------------------------------------------------------
# rectangular seed
# ---------------------------------------------------------------------------

def seed_shape(statics: FleetStatics, cfg: FleetConfig, size: jnp.ndarray,
               center: jnp.ndarray) -> jnp.ndarray:
    """Batched core/search.seed_shape: size [F] int, center [F] int ->
    [F, N] bool rectangle of ~size cells around center."""
    n = cfg.n_cells
    size = jnp.clip(size, 0, n)
    w = statics.rect_w[size]                               # [F]
    h = statics.rect_h[size]
    cp = statics.coords[center, 0]
    ct = statics.coords[center, 1]
    p0 = jnp.clip(cp - w // 2, 0, cfg.n_pan - w)
    t0 = jnp.clip(ct - h // 2, 0, cfg.n_tilt - h)
    px = statics.coords[None, :, 0]                        # [1, N]
    tx = statics.coords[None, :, 1]
    return ((px >= p0[:, None]) & (px < (p0 + w)[:, None])
            & (tx >= t0[:, None]) & (tx < (t0 + h)[:, None]))


# ---------------------------------------------------------------------------
# head/tail shape evolution
# ---------------------------------------------------------------------------

def _evolve_multi(cfg: FleetConfig, statics: FleetStatics, mask, labels,
                  centroids, has_boxes):
    """The >= 2-member head/tail swap loop, all cameras at once."""
    f, n = mask.shape
    # members by descending label, ties toward the lower cell id; the
    # order is frozen at loop entry exactly like the numpy reference
    order = jnp.argsort(jnp.where(mask, -labels, INF), stable=True)
    m = jnp.sum(mask, axis=-1)

    def cond(c):
        # every live iteration breaks, advances the head (at most once per
        # swap), or retires a tail — 2n + 2*max_swaps bounds the loop
        return jnp.any(~c["done"]) & (c["it"] < 2 * n + 2 * cfg.max_swaps)

    def body(c):
        mask, done = c["mask"], c["done"]
        h_i, t_i, thresh = c["h_i"], c["t_i"], c["thresh"]
        failed, swaps = c["failed"], c["swaps"]

        done = done | (h_i >= t_i) | (swaps >= cfg.max_swaps)
        H = order[jnp.arange(f), h_i]
        T = order[jnp.arange(f), t_i]
        lab_h = labels[jnp.arange(f), H]
        lab_t = labels[jnp.arange(f), T]
        live = ~done & (lab_h / jnp.maximum(lab_t, 1e-9) > thresh)
        done = done | (~done & ~live)      # insufficient disparity: break

        scores, cand = _scores(cfg, statics, mask, has_boxes, centroids, H)
        has_cand = jnp.any(cand, axis=-1)
        best = jnp.argmax(jnp.where(cand, scores, -INF), axis=-1)

        # no candidate: first failure advances the head, second ends
        nc = live & ~has_cand
        done = done | (nc & failed)
        advance = nc & ~failed
        h_i = jnp.where(advance, h_i + 1, h_i)
        thresh = jnp.where(advance, cfg.base_threshold, thresh)
        failed = jnp.where(advance, True, failed)

        # candidate: swap if removing the tail keeps the trial contiguous
        wc = live & has_cand
        trial = mask | (_onehot(best, n) & wc[:, None])
        keeps = is_contiguous(trial & ~_onehot(T, n), statics.neighbor8)
        structural = wc & ~keeps
        t_i = jnp.where(structural, t_i - 1, t_i)
        swap = wc & keeps
        mask = jnp.where(swap[:, None], trial & ~_onehot(T, n), mask)
        failed = jnp.where(swap, False, failed)
        swaps = jnp.where(swap, swaps + 1, swaps)
        t_i = jnp.where(swap, t_i - 1, t_i)
        thresh = jnp.where(swap, thresh * cfg.threshold_growth, thresh)

        return {"mask": mask, "done": done, "h_i": h_i, "t_i": t_i,
                "thresh": thresh, "failed": failed, "swaps": swaps,
                "it": c["it"] + 1}

    init = {"mask": mask, "done": m < 2,
            "h_i": jnp.zeros(f, jnp.int32),
            "t_i": jnp.maximum(m - 1, 0).astype(jnp.int32),
            "thresh": jnp.full(f, cfg.base_threshold, jnp.float32),
            "failed": jnp.zeros(f, bool),
            "swaps": jnp.zeros(f, jnp.int32),
            "it": jnp.zeros((), jnp.int32)}
    return lax.while_loop(cond, body, init)["mask"]


def _evolve_single(cfg: FleetConfig, statics: FleetStatics, mask, labels,
                   centroids, has_boxes):
    """1-member drift/jump branch of core/search.evolve_shape."""
    f, n = mask.shape
    H = jnp.argmax(mask, axis=-1)
    lab_h = labels[jnp.arange(f), H]
    best_global = jnp.argmax(labels, axis=-1)
    lab_bg = jnp.max(labels, axis=-1)
    jump = (best_global != H) & (lab_bg > lab_h * 2 * cfg.base_threshold)

    scores, cand = _scores(cfg, statics, mask, has_boxes, centroids, H)
    has_cand = jnp.any(cand, axis=-1)
    best = jnp.argmax(jnp.where(cand, scores, -INF), axis=-1)
    best_score = jnp.max(jnp.where(cand, scores, -INF), axis=-1)
    lab_best = labels[jnp.arange(f), best]
    moving_away = best_score > 1.05
    promising = lab_best > lab_h * cfg.base_threshold
    drift = ~jump & has_cand & (moving_away | promising)

    target = jnp.where(jump, best_global, best)
    move = jump | drift
    moved = (mask & ~_onehot(H, n)) | _onehot(target, n)
    return jnp.where(move[:, None], moved, mask)


def evolve_shape(cfg: FleetConfig, statics: FleetStatics, mask: jnp.ndarray,
                 labels: jnp.ndarray, centroids: jnp.ndarray,
                 has_boxes: jnp.ndarray) -> jnp.ndarray:
    """Batched core/search.evolve_shape. All [F, ...]; returns [F, N]."""
    m = jnp.sum(mask, axis=-1)
    multi = _evolve_multi(cfg, statics, mask, labels, centroids, has_boxes)

    # the 1-member drift branch only exists under degenerate budgets —
    # skip its scoring pass entirely when no camera is in that regime
    def with_single(multi):
        single = _evolve_single(cfg, statics, mask, labels, centroids,
                                has_boxes)
        return jnp.where((m == 1)[:, None], single, multi)

    out = lax.cond(jnp.any(m == 1), with_single, lambda x: x, multi)
    return jnp.where((m == 0)[:, None], mask, out)


# ---------------------------------------------------------------------------
# resize to the budgeted cell count
# ---------------------------------------------------------------------------

def resize_shape(cfg: FleetConfig, statics: FleetStatics, mask: jnp.ndarray,
                 labels: jnp.ndarray, centroids: jnp.ndarray,
                 has_boxes: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Batched core/search.resize_shape: grow to / shrink to target [F]."""
    f, n = mask.shape
    target = jnp.clip(target, 1, n)
    adj_f = statics.neighbor8.astype(jnp.float32)

    # -- grow: add the best-scored neighbor of the highest-label member
    #    that still has free neighbors
    def g_cond(c):
        return jnp.any(~c["stuck"] & (jnp.sum(c["mask"], -1) < target))

    def g_body(c):
        mask, stuck = c["mask"], c["stuck"]
        live = ~stuck & (jnp.sum(mask, -1) < target)
        free = ((~mask).astype(jnp.float32) @ adj_f) > 0       # any free nbr
        eligible = mask & free
        H = jnp.argmax(jnp.where(eligible, labels, -INF), axis=-1)
        ok = jnp.any(eligible, axis=-1)
        scores, cand = _scores(cfg, statics, mask, has_boxes, centroids, H)
        best = jnp.argmax(jnp.where(cand, scores, -INF), axis=-1)
        grow = live & ok
        mask = mask | (_onehot(best, n) & grow[:, None])
        stuck = stuck | (live & ~ok)
        return {"mask": mask, "stuck": stuck}

    mask = lax.while_loop(g_cond, g_body,
                          {"mask": mask, "stuck": jnp.zeros(f, bool)})["mask"]

    # -- shrink: drop the lowest-label member whose removal keeps the
    #    shape connected; if none qualifies, drop the lowest regardless
    def s_cond(c):
        return jnp.any(jnp.sum(c["mask"], -1) > target)

    def s_body(c):
        mask = c["mask"]
        live = jnp.sum(mask, -1) > target
        T = first_removable(mask, labels, statics.neighbor8)
        return {"mask": mask & ~(_onehot(T, n) & live[:, None])}

    return lax.while_loop(s_cond, s_body, {"mask": mask})["mask"]
