"""Fleet-scale MadEye controller — the camera-side loop of paper §3.3
reimplemented as pure-JAX fixed-shape functions over a [F, n_cells] fleet
axis, so one jit'd program steps hundreds-to-thousands of cameras at once
(the numpy reference lives in core/madeye.py and steps one camera per
Python call).

  state.py      controller-state pytree (mirrors MadEyeController state,
                built on core/ewma.EWMAState) + static grid geometry
  shape_ops.py  seed / head-tail evolve / resize as masked vectorized ops
                with static iteration bounds
  step.py       one fleet timestep: budget -> shape -> MST path + shrink
                -> zoom -> rank -> EWMA update
  runner.py     ONE lax.scan episode body behind the observation-provider
                seam (host-materialized EpisodeTables, device-resident
                repro.scene_jax SceneProvider, or DetectorProvider — the
                distilled approximation model scoring rendered crops
                in-step), shardable over a mesh `data` axis
  api.py        the public experiment API: ObservationProvider protocol,
                string-keyed provider registry, declarative FleetRunSpec
                (+ ShardSpec), run_fleet(spec) -> FleetResult

In-scan continual distillation (paper §3.4) plugs in through
`FleetRunSpec(provider="detector", distill=...)` — see repro.learn; the
DistillSpec is re-exported here for convenience.

The one-call entry point:

    from repro.fleet import FleetRunSpec, run_fleet
    result = run_fleet(FleetRunSpec(provider="scene", n_cameras=256,
                                    n_steps=64))
"""
from repro.fleet.state import (
    FleetConfig,
    FleetState,
    FleetStatics,
    WorkloadSpec,
    fleet_config,
    fleet_statics,
    init_fleet,
    workload_spec,
)
from repro.fleet.step import fleet_step
from repro.fleet.runner import (
    DetectorProvider,
    EpisodeTables,
    SceneProvider,
    build_episode_tables,
    fleet_network_traces,
    load_detector_params,
    make_detector_provider,
    make_scene_provider,
    make_tables_provider,
    materialize_scene_tables,
    run_fleet_episode,
    save_detector_params,
    shard_fleet,
    shortlist_windows,
)
from repro.fleet.api import (
    DEFAULT_QUERIES,
    FleetResult,
    FleetRunSpec,
    ObservationProvider,
    PreparedFleetRun,
    ShardSpec,
    available_providers,
    prepare_fleet_run,
    provider_factory,
    register_provider,
    run_fleet,
)
from repro.learn.spec import DistillSpec
