"""Fleet controller state + statics.

`FleetState` is the pytree analogue of the mutable attributes
`MadEyeController.__post_init__` creates — every leaf carries a leading
fleet axis [F] so the whole fleet is one pytree that vmaps/shards/scans.
`FleetStatics` packs the grid geometry the step needs (device arrays,
constant across the episode); `FleetConfig`/`WorkloadSpec` are hashable
python-side configs that jit treats as static.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from typing import NamedTuple

from repro.core import ewma
from repro.core.grid import OrientationGrid
from repro.core.path import prim_mst
from repro.core.rank import TASKS, Workload
from repro.core.search import SearchConfig, best_rect, seed_shape
from repro.core.tradeoff import BudgetConfig
from repro.core.zoom import ZoomConfig
from repro.kernels.neighbor_score.ops import geometry_arrays

NET_WINDOW = 5
NET_DEFAULT_MBPS = 24.0
# last_visit sentinel for cells never explored: far enough in the past
# that staleness bonuses saturate immediately. Shared with the in-scan
# metrics (repro.obs.metrics counts `last_visit > NEVER_VISITED` as
# exploration coverage), so the two can't drift apart.
NEVER_VISITED = -1000


# ---------------------------------------------------------------------------
# static configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetConfig:
    """Everything the step treats as compile-time constant."""
    # grid
    n_pan: int = 5
    n_tilt: int = 5
    pan_step: float = 30.0
    tilt_step: float = 15.0
    fov_scale: float = 2.0
    zoom_levels: tuple = (1.0, 2.0, 3.0)
    # budget (mirrors core/tradeoff.BudgetConfig)
    fps: float = 15.0
    rotation_speed: float = 400.0
    hop_degrees: float = 30.0
    approx_infer_s: float = 0.0067
    backend_infer_s: float = 0.010
    frame_bytes: int = 25_000
    min_send: int = 1
    max_send: int = 4
    pipelined: bool = False
    # search (mirrors core/search.SearchConfig)
    base_threshold: float = 1.25
    threshold_growth: float = 1.25
    max_swaps: int = 8
    # zoom (mirrors core/zoom.ZoomConfig)
    zoom_out_after: float = 3.0
    margin: float = 0.7
    # controller (mirrors core/madeye.MadEyeController; the initial seed
    # size is init_fleet's seed_size argument, not a config field)
    delta_weight: float = 0.5
    scout_every: int = 8
    stale_decay: float = 0.995
    # neighbor-score dispatch (Pallas kernel vs fused jnp reference);
    # kernel_interpret=False compiles the kernel (TPU) instead of running
    # it in the Pallas interpreter (the CPU-safe default)
    use_kernel: bool = False
    kernel_interpret: bool = True

    @property
    def n_cells(self) -> int:
        return self.n_pan * self.n_tilt

    @property
    def timestep(self) -> float:
        return 1.0 / self.fps


def fleet_config(grid: OrientationGrid,
                 budget: BudgetConfig | None = None,
                 search_cfg: SearchConfig | None = None,
                 zoom_cfg: ZoomConfig | None = None,
                 **overrides) -> FleetConfig:
    """Build a FleetConfig from the numpy-side config objects so both
    controller implementations consume identical constants."""
    budget = budget or BudgetConfig()
    search_cfg = search_cfg or SearchConfig()
    zoom_cfg = zoom_cfg or ZoomConfig()
    kw = dict(
        n_pan=grid.n_pan, n_tilt=grid.n_tilt,
        pan_step=grid.pan_step, tilt_step=grid.tilt_step,
        fov_scale=grid.fov_scale, zoom_levels=tuple(zoom_cfg.zoom_levels),
        fps=budget.fps, rotation_speed=budget.rotation_speed,
        hop_degrees=budget.hop_degrees,
        approx_infer_s=budget.approx_infer_s,
        backend_infer_s=budget.backend_infer_s,
        frame_bytes=budget.frame_bytes,
        min_send=budget.min_send, max_send=budget.max_send,
        pipelined=budget.pipelined,
        base_threshold=search_cfg.base_threshold,
        threshold_growth=search_cfg.threshold_growth,
        max_swaps=search_cfg.max_swaps,
        zoom_out_after=zoom_cfg.zoom_out_after, margin=zoom_cfg.margin,
    )
    kw.update(overrides)
    return FleetConfig(**kw)


class WorkloadSpec(NamedTuple):
    """Static query layout: queries[q] reads pair column pair_idx[q] of the
    observation tables and scores with task task_id[q] (index into TASKS).
    pair_cls maps each pair to its object class id — the detector-backed
    provider buckets the shared approximation model's detections into
    pair columns by predicted class (scene_jax.observe.detections_obs)."""
    pairs: tuple            # ((model, obj), ...) — distinct, table order
    pair_idx: tuple         # [Q] int — query -> pair column
    task_id: tuple          # [Q] int — query -> TASKS index
    pair_cls: tuple         # [P] int — pair -> object class (PERSON/CAR)


def workload_spec(workload: Workload) -> WorkloadSpec:
    from repro.data.dataset import OBJ_IDS

    pairs = []
    for q in workload.queries:
        if (q.model, q.obj) not in pairs:
            pairs.append((q.model, q.obj))
    return WorkloadSpec(
        pairs=tuple(pairs),
        pair_idx=tuple(pairs.index((q.model, q.obj))
                       for q in workload.queries),
        task_id=tuple(TASKS.index(q.task) for q in workload.queries),
        pair_cls=tuple(int(OBJ_IDS[obj]) for _, obj in pairs),
    )


# ---------------------------------------------------------------------------
# statics (device arrays, constant across an episode)
# ---------------------------------------------------------------------------

class FleetStatics(NamedTuple):
    centers: jnp.ndarray        # [N, 2] cell centers (degrees)
    dist: jnp.ndarray           # [N, N] Chebyshev rotation distance
    neighbor8: jnp.ndarray      # [N, N] bool — 8-connected lattice
    overlap: jnp.ndarray        # [N, N] FOV overlap at zoom 1
    mst_adj: jnp.ndarray        # [N, N] bool — full-grid MST edges
    d_center: jnp.ndarray       # [N, N] euclidean center distance
    rect_w: jnp.ndarray         # [N + 1] seed-rectangle width per size
    rect_h: jnp.ndarray         # [N + 1] seed-rectangle height per size
    coords: jnp.ndarray         # [N, 2] (pan_i, tilt_i) lattice coords
    nbr_order: jnp.ndarray      # [N, N] cells by descending (dist, id)
                                # from each cell — DFS push order


def _rect_table(grid: OrientationGrid) -> tuple[np.ndarray, np.ndarray]:
    """core/search.best_rect evaluated for every size (seed lookup)."""
    n = grid.n_cells
    ws = np.ones(n + 1, np.int32)
    hs = np.ones(n + 1, np.int32)
    for size in range(n + 1):
        ws[size], hs[size] = best_rect(grid, size)
    return ws, hs


def fleet_statics(grid: OrientationGrid) -> FleetStatics:
    geo = geometry_arrays(grid)
    n = grid.n_cells
    mst = np.zeros((n, n), bool)
    for a, b in prim_mst(grid.angular_distance):
        mst[a, b] = mst[b, a] = True
    ws, hs = _rect_table(grid)
    coords = np.array([grid.cell_coords(i) for i in range(n)], np.int32)
    # static DFS push order: from u, all cells by descending rotation
    # distance, ties toward the higher id — popping then visits nearest
    # first with ties toward the lower id (core/path.subtree_walk's rule).
    # Lexsort, not a composite float key: works at any grid granularity.
    ids = np.arange(n)
    nbr_order = np.stack([
        np.lexsort((-ids, -grid.angular_distance[u])) for u in range(n)
    ]).astype(np.int32)
    return FleetStatics(
        centers=jnp.asarray(grid.centers, jnp.float32),
        dist=jnp.asarray(grid.angular_distance, jnp.float32),
        neighbor8=jnp.asarray(geo["neighbor8"]),
        overlap=jnp.asarray(geo["overlap"]),
        mst_adj=jnp.asarray(mst),
        d_center=jnp.asarray(geo["d_center"]),
        rect_w=jnp.asarray(ws),
        rect_h=jnp.asarray(hs),
        coords=jnp.asarray(coords),
        nbr_order=jnp.asarray(nbr_order),
    )


# ---------------------------------------------------------------------------
# per-camera state pytree
# ---------------------------------------------------------------------------

class FleetState(NamedTuple):
    """Mirror of MadEyeController mutable state; leaves lead with [F]."""
    ewma: ewma.EWMAState        # acc/delta/last/seen, each [F, N]
    shape: jnp.ndarray          # [F, N] bool — current search shape
    current_cell: jnp.ndarray   # [F] int32 — camera orientation
    zoom_idx: jnp.ndarray       # [F, N] int32
    zoomed_since: jnp.ndarray   # [F, N] f32 — seconds at > min zoom
    centroids: jnp.ndarray      # [F, N, 2] — search geometry (sticky)
    has_boxes: jnp.ndarray      # [F, N] bool
    nb_centroid: jnp.ndarray    # [F, N, 2] — zoom geometry (last visit)
    nb_spread: jnp.ndarray      # [F, N] — mean box dist to centroid
    nb_extent: jnp.ndarray      # [F, N] — max box side
    nb_has: jnp.ndarray         # [F, N] bool — boxes seen at last visit
    train_acc: jnp.ndarray      # [F] — backend-reported approx accuracy
    pred_var: jnp.ndarray       # [F] — variance of last predictions
    saw_objects: jnp.ndarray    # [F] bool
    step_idx: jnp.ndarray       # [F] int32
    last_visit: jnp.ndarray     # [F, N] int32
    net_samples: jnp.ndarray    # [F, NET_WINDOW] observed mbps
    net_count: jnp.ndarray      # [F] int32 — filled window slots
    rtt: jnp.ndarray            # [F] f32
    rng: jnp.ndarray            # [F, 2] per-camera jax.random key


def init_fleet(grid: OrientationGrid, n_cameras: int,
               seed_size: int = 6, *, seed: int = 0,
               cam_seeds=None, rng=None) -> FleetState:
    """Same initial conditions as MadEyeController.__post_init__.

    Camera f's PRNG key is fold_in(PRNGKey(seed), cam_seeds[f])
    (cam_seeds defaults to arange) — derived from the camera's own seed,
    never from its position in the fleet array, so the stream a camera
    sees is reproducible and independent of fleet size or shard layout.
    The controller itself is deterministic; the key drives the
    scene-backed observation provider (repro.scene_jax). Pass `rng`
    ([F, 2] keys) to install already-derived camera keys instead —
    make_scene_provider does, so the keys that spawned the initial scene
    state and the keys stepping it in-scan are the same array, not two
    derivations that must stay in sync.
    """
    if n_cameras < 1:
        raise ValueError(f"n_cameras must be >= 1, got {n_cameras}")
    n = grid.n_cells
    f = n_cameras
    if rng is None:
        if cam_seeds is None:
            cam_seeds = np.arange(f)
        cam_seeds = jnp.asarray(np.broadcast_to(cam_seeds, (f,)), jnp.int32)
        rng = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            jax.random.PRNGKey(seed), cam_seeds)
    elif rng.shape[0] != f:
        raise ValueError(f"rng has {rng.shape[0]} keys for {f} cameras")
    shape0 = np.asarray(seed_shape(grid, seed_size), bool)
    cur0 = int(np.flatnonzero(shape0)[0])
    def z_fn(*s, dtype=jnp.float32):
        return jnp.zeros((f, *s), dtype)
    return FleetState(
        ewma=ewma.EWMAState(z_fn(n), z_fn(n), z_fn(n), z_fn(n)),
        shape=jnp.broadcast_to(jnp.asarray(shape0), (f, n)),
        current_cell=jnp.full((f,), cur0, jnp.int32),
        zoom_idx=z_fn(n, dtype=jnp.int32),
        zoomed_since=z_fn(n),
        centroids=z_fn(n, 2),
        has_boxes=z_fn(n, dtype=bool),
        nb_centroid=z_fn(n, 2),
        nb_spread=z_fn(n),
        nb_extent=z_fn(n),
        nb_has=z_fn(n, dtype=bool),
        train_acc=jnp.full((f,), 0.85, jnp.float32),
        pred_var=jnp.full((f,), 0.25, jnp.float32),
        saw_objects=jnp.ones((f,), bool),
        step_idx=z_fn(dtype=jnp.int32),
        last_visit=jnp.full((f, n), NEVER_VISITED, jnp.int32),
        net_samples=z_fn(NET_WINDOW),
        net_count=z_fn(dtype=jnp.int32),
        rtt=jnp.full((f,), 0.02, jnp.float32),
        rng=rng,
    )
