"""Batched inference engine — the TPU-native serving core.

The paper runs approximation models round-robin on a Jetson (Nexus-style
scheduler). The TPU adaptation batches instead: every explored orientation
of every camera in a fleet becomes one row of a single [B, H, W, 3] batch
— the MXU wants one big matmul, not 75 small ones. The fleet dimension is
the leading batch axis and shards over the mesh's `data` axis via pjit
(launch/serve.py wires the mesh); controller state (EWMA labels) is a
pytree with the same leading axis, updated with vmapped pure functions
from core/ewma.py.

`run_fleet_controller` drives the FULL per-timestep controller (shape
search + path + zoom + rank, repro/fleet) for a whole fleet in one jit'd
scan; the EWMA-only helpers below remain for pipelines that rank on the
server side without camera-side shape search.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import DetectorConfig
from repro.core import ewma
from repro.models import detector as det


# Module-level jits, NOT per-engine lambdas: a fresh `jax.jit(lambda ...)`
# per InferenceEngine (the old __post_init__) meant every engine instance
# — and every vmap call site with its own static threshold — carried its
# own compilation cache, so the in-step detector path re-traced per site.
# Hoisted here the cache keys on (cfg, shapes) alone; score_thresh is a
# *traced* scalar, so sweeping thresholds never recompiles
# (tests/test_render_jax.py asserts the cache stays at one entry).

@partial(jax.jit, static_argnames=("cfg",))
def detector_scores(params, cfg: DetectorConfig,
                    images: jnp.ndarray) -> det.Detections:
    """images [B, H, W, 3] -> Detections (static [B, max_boxes, ...])."""
    return det.detector_forward(params, cfg, images)


@partial(jax.jit, static_argnames=("cfg",))
def detector_counts_and_areas(params, cfg: DetectorConfig,
                              images: jnp.ndarray,
                              score_thresh: jnp.ndarray):
    """-> (counts [B], areas [B]) for rank.py consumption."""
    d = det.detector_forward(params, cfg, images)
    keep = d.scores >= score_thresh
    counts = jnp.sum(keep, axis=-1)
    areas = jnp.sum(d.boxes[..., 2] * d.boxes[..., 3] * keep, axis=-1)
    return counts, areas


@dataclass
class InferenceEngine:
    """jit'd detector inference over orientation batches."""
    cfg: DetectorConfig
    params: dict

    def score_batch(self, images: jnp.ndarray) -> det.Detections:
        """images [B, H, W, 3] -> Detections (static [B, max_boxes, ...])."""
        return detector_scores(self.params, self.cfg, images)

    def counts_and_areas(self, images: jnp.ndarray, *,
                         score_thresh: float = 0.5):
        """-> (counts [B], areas [B]) for rank.py consumption."""
        return detector_counts_and_areas(
            self.params, self.cfg, images,
            jnp.asarray(score_thresh, jnp.float32))


# ---------------------------------------------------------------------------
# Fleet-scale EWMA ranking state (vmapped over cameras)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def fleet_update_labels(state: ewma.EWMAState, visited: jnp.ndarray,
                        acc_values: jnp.ndarray) -> ewma.EWMAState:
    """state leaves [C, N]; visited/acc_values [C, N] — C cameras."""
    return jax.vmap(ewma.update)(state, visited, acc_values)


@jax.jit
def fleet_labels(state: ewma.EWMAState) -> jnp.ndarray:
    return jax.vmap(ewma.labels)(state)


def init_fleet_state(n_cameras: int, n_cells: int) -> ewma.EWMAState:
    z = jnp.zeros((n_cameras, n_cells), jnp.float32)
    return ewma.EWMAState(z, z, z, z)


@partial(jax.jit, static_argnames=("k",))
def fleet_topk_cells(labels: jnp.ndarray, k: int = 4):
    """labels [C, N] -> (values [C, k], cells [C, k]) — per-camera ranking."""
    return jax.lax.top_k(labels, k)


def run_fleet_controller(video, workload, tables, budget, trace, *,
                         n_cameras: int, mesh=None,
                         approx_miss: float = 0.12,
                         acc_table=None, max_steps: int | None = None):
    """Drive the full fleet controller (repro.fleet) on a serving
    substrate — the many-camera analogue of pipeline.run_madeye.

    Builds the episode observation tables once on the host, then runs the
    whole episode as a single jit'd lax.scan over an [n_cameras, n_cells]
    fleet. With `mesh`, the fleet axis shards over the mesh `data` axis.
    Returns (final FleetState, FleetStepOut stacked over steps).
    """
    from repro.fleet import (
        build_episode_tables,
        fleet_config,
        fleet_statics,
        init_fleet,
        run_fleet_episode,
        workload_spec,
    )
    tables_ep = build_episode_tables(
        video, workload, tables, budget, trace,
        approx_miss=approx_miss, acc_table=acc_table, max_steps=max_steps)
    cfg = fleet_config(video.grid, budget)
    state = init_fleet(video.grid, n_cameras)
    return run_fleet_episode(cfg, workload_spec(workload),
                             fleet_statics(video.grid), state, tables_ep,
                             mesh=mesh)


def run_fleet_scene_controller(grid, workload, budget, *, n_cameras: int,
                               n_steps: int, mesh=None, seed: int = 0,
                               **scene_kwargs):
    """Drive the fleet controller on the device-resident scene substrate —
    no host materialization: per-camera scenes (repro.scene_jax) advance
    and are observed inside the jit'd episode scan, so episode length and
    fleet heterogeneity cost no host work.

    `scene_kwargs` go to fleet.make_scene_provider (scene_seeds,
    person_speed, n_people, mbps, net_seed, ... — scalars broadcast, [F]
    arrays give per-camera heterogeneity). Returns (final FleetState,
    FleetStepOut stacked over steps).
    """
    from repro.fleet import (
        fleet_config,
        fleet_statics,
        make_scene_provider,
        run_fleet_episode,
        workload_spec,
    )
    cfg = fleet_config(grid, budget)
    provider, state = make_scene_provider(
        grid, workload, cfg, n_cameras=n_cameras, n_steps=n_steps,
        seed=seed, **scene_kwargs)
    return run_fleet_episode(cfg, workload_spec(workload),
                             fleet_statics(grid), state, provider,
                             mesh=mesh)


def run_fleet_detector_controller(grid, workload, budget, *,
                                  n_cameras: int, n_steps: int, mesh=None,
                                  seed: int = 0, det_cfg=None,
                                  det_params=None, **scene_kwargs):
    """Drive the fleet controller with the distilled approximation model
    in the loop — the paper's full camera-side pipeline (§3.4): every
    candidate orientation is *rendered* from the device-resident scene
    and *scored* by the detector network (models/detector) inside the
    jit'd episode scan; the controller ranks on those detections instead
    of precomputed teacher tables. Oracle accuracy still comes from the
    scene teachers, as backend feedback.

    det_cfg defaults to the madeye-approx smoke config (64 px crops);
    det_params are initialized from `seed` when not given — pass a
    distilled checkpoint for a trained camera. `scene_kwargs` go to
    fleet.make_detector_provider (same scene/network heterogeneity knobs
    as the scene controller). Returns (final FleetState, FleetStepOut
    stacked over steps).
    """
    from repro.fleet import (
        fleet_config,
        fleet_statics,
        make_detector_provider,
        run_fleet_episode,
        workload_spec,
    )
    cfg = fleet_config(grid, budget)
    scene_kwargs.setdefault("det_seed", seed)
    provider, state = make_detector_provider(
        grid, workload, cfg, n_cameras=n_cameras, n_steps=n_steps,
        seed=seed, det_cfg=det_cfg, det_params=det_params, **scene_kwargs)
    return run_fleet_episode(cfg, workload_spec(workload),
                             fleet_statics(grid), state, provider,
                             mesh=mesh)


@partial(jax.jit, static_argnames=("k_send",))
def fleet_step(state: ewma.EWMAState, counts: jnp.ndarray,
               areas: jnp.ndarray, visited: jnp.ndarray, *,
               k_send: int = 2):
    """One fleet-wide ranking timestep, fully on-device (pjit-able: shard
    the camera axis over `data`).

    counts/areas [C, N] — approximation-model outputs for the explored
    cells of every camera (zeros elsewhere); visited [C, N] bool.
    Returns (new_state, send_cells [C, k], pred_acc [C, N]).

    This is the TPU-native heart of the controller: the per-task relative
    scoring of core/rank.py for the counting abstraction, the EWMA label
    update, and the top-k selection — one fused program for 10k cameras
    instead of 10k Python loops.
    """
    # relative predicted accuracy per camera (count task, §3.1)
    cmax = jnp.max(jnp.where(visited, counts, 0.0), axis=1, keepdims=True)
    cscore = jnp.where(cmax > 0, counts / jnp.maximum(cmax, 1e-9), 0.0)
    amax = jnp.max(jnp.where(visited, areas, 0.0), axis=1, keepdims=True)
    ascore = jnp.where(amax > 0, areas / jnp.maximum(amax, 1e-9), 0.0)
    pred = 0.7 * cscore + 0.3 * ascore
    pred = jnp.where(visited, pred, 0.0)

    new_state = jax.vmap(ewma.update)(state, visited, pred)
    # rank only explored cells (unexplored get -inf)
    masked = jnp.where(visited, pred, -jnp.inf)
    _, cells = jax.lax.top_k(masked, k_send)
    return new_state, cells, pred
