"""Batched inference engine — the TPU-native serving core.

The paper runs approximation models round-robin on a Jetson (Nexus-style
scheduler). The TPU adaptation batches instead: every explored orientation
of every camera in a fleet becomes one row of a single [B, H, W, 3] batch
— the MXU wants one big matmul, not 75 small ones. The fleet dimension is
the leading batch axis and shards over the mesh's `data` axis via pjit
(launch/serve.py wires the mesh); controller state (EWMA labels) is a
pytree with the same leading axis, updated with vmapped pure functions
from core/ewma.py.

`run_fleet_controller` drives the FULL per-timestep controller (shape
search + path + zoom + rank, repro/fleet) for a whole fleet in one jit'd
scan; the EWMA-only helpers below remain for pipelines that rank on the
server side without camera-side shape search.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import DetectorConfig
from repro.core import ewma
from repro.models import detector as det
from repro.obs import span


# Module-level jits, NOT per-engine lambdas: a fresh `jax.jit(lambda ...)`
# per InferenceEngine (the old __post_init__) meant every engine instance
# — and every vmap call site with its own static threshold — carried its
# own compilation cache, so the in-step detector path re-traced per site.
# Hoisted here the cache keys on (cfg, shapes) alone; score_thresh is a
# *traced* scalar, so sweeping thresholds never recompiles
# (tests/test_render_jax.py asserts the cache stays at one entry).

@partial(jax.jit, static_argnames=("cfg",))
def detector_scores(params, cfg: DetectorConfig,
                    images: jnp.ndarray) -> det.Detections:
    """images [B, H, W, 3] -> Detections (static [B, max_boxes, ...])."""
    return det.detector_forward(params, cfg, images)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2,))
def detector_scores_tokens(params, cfg: DetectorConfig,
                           tokens: jnp.ndarray) -> det.Detections:
    """Patch-embedding tokens [B, P, D] -> Detections.

    The candidate-sparse fast path's ONE batched forward: the fleet
    provider flattens its [F, K] shortlisted crops to B = F*K token rows
    (emitted by kernels/crop_patchify without materializing pixels) and
    scores them in a single program instead of a serial per-chunk
    lax.map. The token buffer is donated — at the top level XLA reuses
    it for activations, so peak memory stays at the activation slab
    rather than tokens + activations (inside an enclosing jit, e.g. the
    episode scan, donation is a no-op and XLA schedules as usual).
    """
    return det.detector_forward_tokens(params, cfg, tokens)


@partial(jax.jit, static_argnames=("cfg",))
def detector_counts_and_areas(params, cfg: DetectorConfig,
                              images: jnp.ndarray,
                              score_thresh: jnp.ndarray):
    """-> (counts [B], areas [B]) for rank.py consumption."""
    d = det.detector_forward(params, cfg, images)
    keep = d.scores >= score_thresh
    counts = jnp.sum(keep, axis=-1)
    areas = jnp.sum(d.boxes[..., 2] * d.boxes[..., 3] * keep, axis=-1)
    return counts, areas


@dataclass
class InferenceEngine:
    """jit'd detector inference over orientation batches."""
    cfg: DetectorConfig
    params: dict

    def score_batch(self, images: jnp.ndarray) -> det.Detections:
        """images [B, H, W, 3] -> Detections (static [B, max_boxes, ...])."""
        return detector_scores(self.params, self.cfg, images)

    def counts_and_areas(self, images: jnp.ndarray, *,
                         score_thresh: float = 0.5):
        """-> (counts [B], areas [B]) for rank.py consumption."""
        return detector_counts_and_areas(
            self.params, self.cfg, images,
            jnp.asarray(score_thresh, jnp.float32))


# ---------------------------------------------------------------------------
# Fleet-scale EWMA ranking state (vmapped over cameras)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def fleet_update_labels(state: ewma.EWMAState, visited: jnp.ndarray,
                        acc_values: jnp.ndarray) -> ewma.EWMAState:
    """state leaves [C, N]; visited/acc_values [C, N] — C cameras."""
    return jax.vmap(ewma.update)(state, visited, acc_values)


@jax.jit
def fleet_labels(state: ewma.EWMAState) -> jnp.ndarray:
    return jax.vmap(ewma.labels)(state)


def init_fleet_state(n_cameras: int, n_cells: int) -> ewma.EWMAState:
    z = jnp.zeros((n_cameras, n_cells), jnp.float32)
    return ewma.EWMAState(z, z, z, z)


@partial(jax.jit, static_argnames=("k",))
def fleet_topk_cells(labels: jnp.ndarray, k: int = 4):
    """labels [C, N] -> (values [C, k], cells [C, k]) — per-camera ranking."""
    return jax.lax.top_k(labels, k)


# The three run_fleet_*_controller functions are thin shims over the
# unified experiment API (repro.fleet.api): each builds a declarative
# FleetRunSpec for its provider and returns the raw (final FleetState,
# FleetStepOut) pair it always returned — via prepare_fleet_run +
# .episode(), so the outputs stay on device with none of run_fleet's
# host-side summarization. New code should construct FleetRunSpec
# directly and keep the typed FleetResult.

def run_fleet_controller(video, workload, tables, budget, trace, *,
                         n_cameras: int, mesh=None,
                         approx_miss: float = 0.12,
                         acc_table=None, max_steps: int | None = None):
    """Fleet controller on a prebuilt host serving substrate — the
    many-camera analogue of pipeline.run_madeye, now a shim over
    `run_fleet` with the `tables` provider (the prebuilt video/tables/
    trace objects ride through provider_kwargs). Returns (final
    FleetState, FleetStepOut stacked over steps)."""
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    spec = FleetRunSpec.from_objects(
        "tables", n_cameras=n_cameras, n_steps=max_steps,
        grid=video.grid, workload=workload, budget=budget,
        video=video, tables=tables, trace=trace, acc_table=acc_table,
        approx_miss=approx_miss)
    with span("engine/fleet_controller", provider="tables"):
        return prepare_fleet_run(spec, mesh=mesh).episode()


def run_fleet_scene_controller(grid, workload, budget, *, n_cameras: int,
                               n_steps: int, mesh=None, seed: int = 0,
                               **scene_kwargs):
    """Fleet controller on the device-resident scene substrate — a shim
    over `run_fleet` with the `scene` provider: per-camera scenes
    (repro.scene_jax) advance and are observed inside the jit'd episode
    scan, so episode length and fleet heterogeneity cost no host work.

    `scene_kwargs` go to fleet.make_scene_provider (scene_seeds,
    person_speed, n_people, mbps, net_seed, ... — scalars broadcast, [F]
    arrays give per-camera heterogeneity). Returns (final FleetState,
    FleetStepOut stacked over steps).
    """
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    spec = FleetRunSpec.from_objects(
        "scene", n_cameras=n_cameras, n_steps=n_steps, seed=seed,
        grid=grid, workload=workload, budget=budget, **scene_kwargs)
    with span("engine/fleet_controller", provider="scene"):
        return prepare_fleet_run(spec, mesh=mesh).episode()


def run_fleet_detector_controller(grid, workload, budget, *,
                                  n_cameras: int, n_steps: int, mesh=None,
                                  seed: int = 0, det_cfg=None,
                                  det_params=None, distill=None,
                                  **scene_kwargs):
    """Fleet controller with the approximation model in the loop — a
    shim over `run_fleet` with the `detector` provider, the paper's full
    camera-side pipeline (§3.4): every candidate orientation is
    *rendered* from the device-resident scene and *scored* by the
    detector network (models/detector) inside the jit'd episode scan;
    the controller ranks on those detections instead of precomputed
    teacher tables. Oracle accuracy still comes from the scene teachers,
    as backend feedback.

    det_cfg defaults to the madeye-approx smoke config (64 px crops);
    det_params are initialized from `seed` when not given — pass a
    distilled checkpoint (pytree or .npz path) for a trained camera.
    `distill` (True / DistillSpec / dict, see repro.learn) turns on
    in-scan continual distillation: per-camera detector heads train
    against the scene teachers inside the scan, and the episode return
    grows the (extras, final carry) tail documented on
    fleet.run_fleet_episode. `scene_kwargs` go to
    fleet.make_detector_provider (same scene/network heterogeneity
    knobs as the scene controller). Returns (final FleetState,
    FleetStepOut stacked over steps) on frozen runs.
    """
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    scene_kwargs.setdefault("det_seed", seed)
    spec = FleetRunSpec.from_objects(
        "detector", n_cameras=n_cameras, n_steps=n_steps, seed=seed,
        grid=grid, workload=workload, budget=budget,
        det_cfg=det_cfg, det_params=det_params, distill=distill,
        **scene_kwargs)
    with span("engine/fleet_controller", provider="detector"):
        return prepare_fleet_run(spec, mesh=mesh).episode()


@partial(jax.jit, static_argnames=("k_send",))
def fleet_step(state: ewma.EWMAState, counts: jnp.ndarray,
               areas: jnp.ndarray, visited: jnp.ndarray, *,
               k_send: int = 2):
    """One fleet-wide ranking timestep, fully on-device (pjit-able: shard
    the camera axis over `data`).

    counts/areas [C, N] — approximation-model outputs for the explored
    cells of every camera (zeros elsewhere); visited [C, N] bool.
    Returns (new_state, send_cells [C, k], pred_acc [C, N]).

    This is the TPU-native heart of the controller: the per-task relative
    scoring of core/rank.py for the counting abstraction, the EWMA label
    update, and the top-k selection — one fused program for 10k cameras
    instead of 10k Python loops.
    """
    # relative predicted accuracy per camera (count task, §3.1)
    cmax = jnp.max(jnp.where(visited, counts, 0.0), axis=1, keepdims=True)
    cscore = jnp.where(cmax > 0, counts / jnp.maximum(cmax, 1e-9), 0.0)
    amax = jnp.max(jnp.where(visited, areas, 0.0), axis=1, keepdims=True)
    ascore = jnp.where(amax > 0, areas / jnp.maximum(amax, 1e-9), 0.0)
    pred = 0.7 * cscore + 0.3 * ascore
    pred = jnp.where(visited, pred, 0.0)

    new_state = jax.vmap(ewma.update)(state, visited, pred)
    # rank only explored cells (unexplored get -inf)
    masked = jnp.where(visited, pred, -jnp.inf)
    _, cells = jax.lax.top_k(masked, k_send)
    return new_state, cells, pred
