"""Teacher (workload) model zoo — biased oracles over simulator ground truth.

The paper's teachers are real CNNs (SSD, Faster-RCNN, YOLOv4, Tiny-YOLOv4
x {VOC, COCO}); offline we model each as a *deterministic biased oracle*:
a detector whose per-object detection probability is a saturating function
of apparent size with model-specific thresholds, plus localization noise
and false positives. This preserves exactly the properties MadEye's design
leans on (paper §2.3 C2):

  * different models discern different objects at the same orientation
    (different a_min / a_sat / p_max);
  * smaller objects are harder for everyone [80];
  * results flicker between consecutive frames [6, 76] (the per-frame
    hash component);
  * per-(model, class) biases diverge (hash-derived quirk factors).

Determinism: every random draw is a hash of (object id, model, frame
bucket), so the same video + workload always yields identical detections —
required for the relative-accuracy metrics to be reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np



def _hash01(*keys) -> float:
    """Stable FNV-1a over the stringified keys (process-independent —
    Python's built-in hash() is salted per process and must not be used)."""
    h = 1469598103934665603
    for b in "|".join(map(str, keys)).encode():
        h ^= b
        h = (h * 1099511628211) & 0xFFFFFFFFFFFFFFFF
    return (h & 0xFFFFFFFF) / 2 ** 32


@dataclass(frozen=True)
class TeacherProfile:
    name: str
    a_min: float          # apparent size floor (nothing below is seen)
    a_sat: float          # apparent size where detection prob saturates
    p_max: float          # plateau detection probability
    loc_sigma: float      # localization noise (fraction of box size)
    fp_rate: float        # false positives per (cell, frame)
    flicker: float = 0.4  # weight of the per-frame-bucket hash component

    def class_quirk(self, cls: int) -> float:
        """Deterministic per-(model, class) bias multiplier on a_min."""
        return 0.85 + 0.3 * _hash01(self.name, "quirk", int(cls))

    def detect_prob(self, apparent: np.ndarray, cls: int) -> np.ndarray:
        a0 = self.a_min * self.class_quirk(cls)
        a1 = self.a_sat * self.class_quirk(cls)
        x = np.clip((apparent - a0) / max(a1 - a0, 1e-6), 0.0, 1.0)
        return self.p_max * x


TEACHERS = {
    "frcnn": TeacherProfile("frcnn", 0.040, 0.12, 0.95, 0.010, 0.02),
    "yolov4": TeacherProfile("yolov4", 0.050, 0.15, 0.92, 0.015, 0.03),
    "ssd": TeacherProfile("ssd", 0.080, 0.20, 0.88, 0.020, 0.04),
    "tiny-yolov4": TeacherProfile("tiny-yolov4", 0.110, 0.28, 0.80, 0.030,
                                  0.06),
}


def run_teacher(profile: TeacherProfile, gt_cell: dict, t: int, cls: int,
                *, flicker_bucket: int = 3) -> dict:
    """Run one teacher on one orientation view (exact GT in, biased out).

    Returns dict(ids [K], boxes [K,4], count, quality) — `quality` is the
    mean localization IoU proxy in [0,1] used by the mAP scoring.
    """
    mask = gt_cell["classes"] == cls
    apparent = gt_cell["apparent"][mask]
    ids = gt_cell["ids"][mask]
    boxes = gt_cell["boxes"][mask]

    p = profile.detect_prob(apparent, cls)
    bucket = t // flicker_bucket
    draws = np.array([
        (1 - profile.flicker) * _hash01(int(i), profile.name, "base")
        + profile.flicker * _hash01(int(i), profile.name, bucket)
        for i in ids]) if ids.size else np.zeros(0)
    det = draws < p

    out_ids = ids[det]
    out_boxes = boxes[det].copy()
    # localization noise (deterministic per id+bucket)
    if out_ids.size:
        jit = np.array([
            [_hash01(int(i), profile.name, bucket, ax) - 0.5
             for ax in range(4)] for i in out_ids])
        out_boxes[:, :2] += jit[:, :2] * profile.loc_sigma * 2
        out_boxes[:, 2:] *= 1.0 + jit[:, 2:] * profile.loc_sigma * 2
        quality = float(np.clip(
            1.0 - np.abs(jit).mean() * profile.loc_sigma * 20, 0.5, 1.0))
    else:
        quality = 1.0

    # false positives (hash-rate per cell-frame)
    n_fp = int(_hash01("fp", profile.name, t, int(gt_cell.get("cell", -1)))
               < profile.fp_rate)
    if n_fp:
        fx = _hash01("fpx", profile.name, t)
        fy = _hash01("fpy", profile.name, t)
        fp_box = np.array([[fx, fy, 0.05, 0.08]])
        out_boxes = np.concatenate([out_boxes, fp_box], axis=0)
        out_ids = np.concatenate([out_ids, [-1]])

    return {
        "ids": out_ids,
        "boxes": out_boxes,
        "count": int(out_ids.size),
        "quality": quality,
    }


def approx_observation(teacher_out: dict, *, miss_rate: float = 0.12,
                       seed_key=(0,)) -> dict:
    """Degrade a teacher output into what the *approximation model* would
    produce — the student mimics the teacher but with extra misses (its
    3.9M params can't match the teacher everywhere). Deterministic."""
    ids = teacher_out["ids"]
    keep = np.array([
        _hash01("approx", int(i), *seed_key) >= miss_rate for i in ids],
        bool) if ids.size else np.zeros(0, bool)
    return {
        "ids": ids[keep],
        "boxes": teacher_out["boxes"][keep],
        "count": int(keep.sum()),
        "quality": teacher_out["quality"] * 0.95,
    }
