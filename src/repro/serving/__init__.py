from repro.serving.accuracy import (
    detection_tables,
    evaluate_selection,
    query_acc_table,
    workload_acc_table,
)
from repro.serving.pipeline import (
    ZOOM_LEVELS,
    RunResult,
    run_madeye,
    run_scheme,
)
from repro.serving.teachers import TEACHERS
from repro.serving.transport import NetworkTrace
