"""Simulated PTZ camera: rotation physics + capture accounting.

The controller plans in grid cells; the camera tracks continuous angles
and charges rotation time with the Chebyshev metric (pan/tilt motors run
concurrently). Digital zoom is instantaneous (ePTZ; paper §2.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.grid import OrientationGrid


@dataclass
class PTZCamera:
    grid: OrientationGrid
    rotation_speed: float = 400.0       # deg/s
    capture_s: float = 0.002            # sensor readout per frame
    angle: np.ndarray = field(default=None)   # (pan, tilt) degrees
    zoom: float = 1.0

    def __post_init__(self):
        if self.angle is None:
            mid = self.grid.cell_index(self.grid.n_pan // 2,
                                       self.grid.n_tilt // 2)
            self.angle = self.grid.centers[mid].copy()

    @property
    def cell(self) -> int:
        d = np.abs(self.grid.centers - self.angle).max(-1)
        return int(np.argmin(d))

    def move_to(self, cell: int, zoom: float = 1.0) -> float:
        """Rotate to a cell center; returns seconds spent."""
        target = self.grid.centers[cell]
        dt = float(np.abs(target - self.angle).max() / self.rotation_speed)
        self.angle = target.copy()
        self.zoom = zoom
        return dt

    def sweep(self, cells: list, zooms: list | None = None) -> float:
        """Visit cells in order; returns total rotation + capture time."""
        zooms = zooms if zooms is not None else [1.0] * len(cells)
        t = 0.0
        for c, z in zip(cells, zooms):
            t += self.move_to(int(c), float(z)) + self.capture_s
        return t
