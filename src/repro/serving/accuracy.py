"""Ground-truth accuracy computation (paper §2.1 metrics, §5.1 methodology).

Per-frame, per-orientation accuracy is *relative to the best orientation at
that instant* — e.g. a counting query's accuracy at a cell is its detected
count over the max detected count across all (cell, zoom) orientations.
Detection queries consolidate boxes across orientations into a global view,
de-duplicate (we have object identity from the oracle teachers; ambiguous
overlaps fall back to the box_iou kernel), and score each orientation's
mAP proxy against that global set.

Aggregate counting is evaluated once per video: unique object ids captured
by the frames a scheme shipped vs unique ids present in the whole video.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.rank import Workload
from repro.data.dataset import OBJ_IDS, Video
from repro.serving.teachers import TEACHERS, run_teacher


@dataclass
class DetectionTable:
    """dets[z][t][cell] -> teacher output dict for one (model, obj)."""
    model: str
    obj: str
    dets: dict


def detection_tables(video: Video, workload: Workload,
                     zoom_levels=(1.0, 2.0, 3.0)) -> dict:
    """Precompute teacher detections for every query x (t, cell, zoom)."""
    tables = {}
    for q in workload.queries:
        key = (q.model, q.obj)
        if key in tables:
            continue
        prof = TEACHERS[q.model]
        cls = OBJ_IDS[q.obj]
        dets = {}
        for z in zoom_levels:
            per_t = []
            for t in range(video.n_frames):
                row = []
                for c in range(video.grid.n_cells):
                    gt = dict(video.gt_zoom[z][t][c])
                    gt["cell"] = c
                    row.append(run_teacher(prof, gt, t, cls))
                per_t.append(row)
            dets[z] = per_t
        tables[key] = DetectionTable(q.model, q.obj, dets)
    return tables


# ---------------------------------------------------------------------------
# Per-task relative accuracy tables: acc[t, cell, zoom]
# ---------------------------------------------------------------------------

def _counts(table: DetectionTable, t: int, zoom_levels) -> np.ndarray:
    return np.array([[table.dets[z][t][c]["count"]
                      for z in zoom_levels]
                     for c in range(len(table.dets[zoom_levels[0]][t]))])


def query_acc_table(video: Video, table: DetectionTable, task: str,
                    zoom_levels=(1.0, 2.0, 3.0)) -> np.ndarray:
    """[T, n_cells, n_zoom] relative accuracy for a frame-level task."""
    T = video.n_frames
    N = video.grid.n_cells
    Z = len(zoom_levels)
    acc = np.zeros((T, N, Z))
    for t in range(T):
        counts = _counts(table, t, zoom_levels)          # [N, Z]
        if task == "binary":
            if counts.max() == 0:
                acc[t] = 1.0          # correct "no" everywhere
            else:
                acc[t] = (counts > 0).astype(float)
        elif task in ("count", "agg_count"):
            m = counts.max()
            acc[t] = counts / m if m > 0 else 1.0
        elif task == "detect":
            # global de-duplicated detected set (ids from oracle teachers;
            # fp ids < 0 are excluded from the global set)
            global_ids = set()
            quality = np.zeros((N, Z))
            rec = np.zeros((N, Z))
            for c in range(N):
                for zi, z in enumerate(zoom_levels):
                    d = table.dets[z][t][c]
                    global_ids.update(int(i) for i in d["ids"] if i >= 0)
            for c in range(N):
                for zi, z in enumerate(zoom_levels):
                    d = table.dets[z][t][c]
                    found = {int(i) for i in d["ids"] if i >= 0}
                    rec[c, zi] = (len(found) / len(global_ids)
                                  if global_ids else 1.0)
                    quality[c, zi] = d["quality"]
            score = rec * quality
            m = score.max()
            acc[t] = score / m if m > 0 else 1.0
        else:
            raise ValueError(task)
    return acc


def workload_acc_table(video: Video, workload: Workload, tables: dict,
                       zoom_levels=(1.0, 2.0, 3.0)) -> np.ndarray:
    """[T, n_cells, n_zoom] mean relative accuracy over the workload's
    frame-level queries (aggregate counting is video-level: evaluated by
    `aggregate_count_accuracy`, its table contribution uses the count
    proxy as in §2.1)."""
    acc = None
    for q in workload.queries:
        t = query_acc_table(video, tables[(q.model, q.obj)], q.task,
                            zoom_levels)
        acc = t if acc is None else acc + t
    return acc / len(workload.queries)


# ---------------------------------------------------------------------------
# Aggregate counting (video-level) + end-to-end selection scoring
# ---------------------------------------------------------------------------

def aggregate_count_accuracy(video: Video, table: DetectionTable,
                             visited: dict, zoom_levels=(1.0, 2.0, 3.0)
                             ) -> float:
    """visited: {frame_idx: [(cell, zoom_idx), ...]} actually shipped.

    Accuracy = |unique detected ids over shipped frames| / |unique ids
    detectable anywhere in the whole video by this teacher| (§5.1)."""
    total_ids, got_ids = set(), set()
    for t in range(video.n_frames):
        for c in range(video.grid.n_cells):
            for z in zoom_levels:
                total_ids.update(
                    int(i) for i in table.dets[z][t][c]["ids"] if i >= 0)
    for t, sent in visited.items():
        for (c, zi) in sent:
            z = zoom_levels[zi]
            got_ids.update(
                int(i) for i in table.dets[z][t][c]["ids"] if i >= 0)
    if not total_ids:
        return 1.0
    return len(got_ids) / len(total_ids)


def evaluate_selection(video: Video, workload: Workload, tables: dict,
                       visited: dict, zoom_levels=(1.0, 2.0, 3.0)) -> float:
    """Workload accuracy for an arbitrary selection scheme.

    visited: {frame_idx: [(cell, zoom_idx), ...]} shipped at each
    *response* frame (the response rate subsamples the video rate).
    Frame-level queries score the best shipped orientation per response
    frame (the backend keeps the max); aggregate counting scores once per
    video.
    """
    frames = sorted(visited)
    per_query = []
    for q in workload.queries:
        table = tables[(q.model, q.obj)]
        if q.task == "agg_count":
            per_query.append(
                aggregate_count_accuracy(video, table, visited, zoom_levels))
            continue
        acc = query_acc_table(video, table, q.task, zoom_levels)
        vals = []
        for t in frames:
            sent = visited[t]
            if not sent:
                vals.append(0.0)
                continue
            vals.append(max(acc[t, c, zi] for (c, zi) in sent))
        per_query.append(float(np.mean(vals)) if vals else 0.0)
    return float(np.mean(per_query))
