"""Network simulation: fixed-capacity links + synthetic mobile traces.

Mirrors the paper's Mahimahi setup ({24-60 Mbps, 5-20 ms} fixed links and
real-world mobile traces). The pipeline asks for per-timestep capacity and
charges transfer time = RTT + bytes/rate; MadEye's NetworkEstimator sees
the *observed* rates (harmonic mean window), never the trace itself.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def ar1_mobile_trace(T: int, base, rng: np.random.Generator) -> np.ndarray:
    """The LTE-ish capacity model shared by `NetworkTrace.mobile` and the
    fleet's per-camera traces: AR(1) around `base` (scalar or [F]) with
    1% deep fades, clipped to [1, 2*base]. Returns [T, *base.shape]."""
    base = np.asarray(base, np.float64)
    x = np.empty((T,) + base.shape)
    x[0] = base
    for t in range(1, T):
        x[t] = 0.9 * x[t - 1] + 0.1 * base + rng.normal(0, 3.0, base.shape)
        fade = rng.random(base.shape) < 0.01
        x[t] = np.where(fade, x[t] * 0.3, x[t])
    return np.clip(x, 1.0, base * 2)


@dataclass
class NetworkTrace:
    mbps: np.ndarray        # [T] capacity per timestep
    rtt_s: float = 0.02

    @classmethod
    def fixed(cls, mbps: float, rtt_ms: float, T: int) -> "NetworkTrace":
        return cls(np.full(T, float(mbps)), rtt_ms / 1e3)

    @classmethod
    def mobile(cls, T: int, base_mbps: float = 24.0, rtt_ms: float = 20.0,
               seed: int = 0) -> "NetworkTrace":
        """LTE-ish trace: AR(1) around base with occasional deep fades."""
        x = ar1_mobile_trace(T, base_mbps, np.random.default_rng(seed))
        return cls(x, rtt_ms / 1e3)

    def transfer_time(self, t: int, n_bytes: int) -> float:
        rate = self.mbps[min(t, len(self.mbps) - 1)]
        return self.rtt_s + n_bytes * 8 / (rate * 1e6)

    def observed_mbps(self, t: int) -> float:
        return float(self.mbps[min(t, len(self.mbps) - 1)])
