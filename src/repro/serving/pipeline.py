"""End-to-end MadEye serving loop (paper Fig. 8).

Per timestep: the controller plans shape/zoom/path -> the camera sweeps the
orientations -> approximation-model proxies score each (degraded teacher
outputs — the student mimics the teacher, §3.1) -> top-k frames ship over
the network trace -> the backend scores true workload accuracy and feeds
rank-agreement (training accuracy) back to the controller.

`run_madeye` is the reference single-camera loop used by every benchmark;
`run_scheme` evaluates the baselines on identical substrate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import baselines as bl
from repro.core.madeye import MadEyeController, Observation
from repro.core.rank import Workload
from repro.core.tradeoff import BudgetConfig
from repro.data.dataset import Video, largest_object_table, motion_table
from repro.data.render import boxes_to_scene
from repro.serving import accuracy as acc_mod
from repro.serving.camera import PTZCamera
from repro.serving.transport import NetworkTrace

ZOOM_LEVELS = (1.0, 2.0, 3.0)


@dataclass
class RunResult:
    accuracy: float
    visited: dict                 # {frame: [(cell, zoom_idx)]} shipped
    explored: dict                # {frame: [cells]} explored
    frames_sent: int
    mean_shape: float
    best_explored_rate: float


def _observation_from_tables(tables, workload: Workload, grid, t, cell,
                             zoom_idx, approx_miss: float) -> Observation:
    from repro.serving.teachers import approx_observation
    z = ZOOM_LEVELS[zoom_idx]
    counts, areas = {}, {}
    all_centers, all_sizes = [], []
    for key in {(q.model, q.obj) for q in workload.queries}:
        det = tables[key].dets[z][t][cell]
        ap = approx_observation(det, miss_rate=approx_miss,
                                seed_key=(t, cell))
        counts[key] = ap["count"]
        boxes = ap["boxes"]
        areas[key] = float((boxes[:, 2] * boxes[:, 3]).sum()) if len(boxes) \
            else 0.0
        if len(boxes):
            c, s = boxes_to_scene(boxes, grid, cell, z)
            all_centers.append(c)
            all_sizes.append(s)
    if all_centers:
        centers = np.concatenate(all_centers, 0)
        sizes = np.concatenate(all_sizes, 0)
    else:
        centers = np.zeros((0, 2))
        sizes = np.zeros((0, 2))
    return Observation(
        counts=counts, areas=areas,
        centroid=centers.mean(0) if len(centers) else np.zeros(2),
        has_boxes=len(centers) > 0,
        box_centers=centers, box_sizes=sizes)


def run_madeye(video: Video, workload: Workload, tables: dict,
               budget: BudgetConfig, trace: NetworkTrace, *,
               approx_miss: float = 0.12,
               acc_table: np.ndarray | None = None) -> RunResult:
    grid = video.grid
    ctrl = MadEyeController(grid, workload, budget=budget)
    camera = PTZCamera(grid, rotation_speed=budget.rotation_speed)
    if acc_table is None:
        acc_table = acc_mod.workload_acc_table(video, workload, tables,
                                               ZOOM_LEVELS)
    T = video.n_frames
    # the controller runs once per RESPONSE timestep; the video advances
    # at its own rate in between (stride frames per timestep)
    stride = max(1, int(round(video.fps / budget.fps)))
    visited, explored_hist = {}, {}
    shape_sizes, best_hits, sent_total = [], [], 0

    for t in range(0, T, stride):
        ctrl.report_network(trace.observed_mbps(t), trace.rtt_s)

        def observe(cells, zooms, _t=t):
            return [_observation_from_tables(
                tables, workload, grid, _t, c, int(zi), approx_miss)
                for c, zi in zip(cells, zooms)]

        res = ctrl.step(observe)
        camera.sweep(res.explored)
        zoom_of = {c: int(z) for c, z in zip(res.explored, res.zooms)}
        sent = [(c, zoom_of[c]) for c in res.sent]
        visited[t] = sent
        explored_hist[t] = list(res.explored)
        sent_total += len(sent)
        shape_sizes.append(len(res.explored))

        # backend feedback: did the approx ranking pick the truly-best
        # explored orientation? (training-accuracy proxy, §3.3)
        if len(res.explored) > 1:
            true_vals = [acc_table[t, c, zoom_of[c]] for c in res.explored]
            agree = float(res.explored[int(np.argmax(res.pred_acc))]
                          == res.explored[int(np.argmax(true_vals))])
            ctrl.report_train_acc(0.9 * ctrl.train_acc + 0.1 * agree)

        best_cell = int(np.argmax(acc_table[t].max(-1)))
        best_hits.append(best_cell in res.explored)

    accuracy = acc_mod.evaluate_selection(video, workload, tables, visited,
                                          ZOOM_LEVELS)
    return RunResult(accuracy, visited, explored_hist, sent_total,
                     float(np.mean(shape_sizes)), float(np.mean(best_hits)))


def run_madeye_topk(video: Video, workload: Workload, tables: dict,
                    budget: BudgetConfig, trace: NetworkTrace, k: int, *,
                    approx_miss: float = 0.12,
                    acc_table: np.ndarray | None = None) -> RunResult:
    """MadEye-k (Table 1): fixed number of frames shipped per timestep."""
    b = BudgetConfig(**{**budget.__dict__, "min_send": k, "max_send": k})
    return run_madeye(video, workload, tables, b, trace,
                      approx_miss=approx_miss, acc_table=acc_table)


# ---------------------------------------------------------------------------
# Baseline harness on the same substrate
# ---------------------------------------------------------------------------

def run_scheme(video: Video, workload: Workload, tables: dict, scheme: str,
               *, k: int = 1, budget: BudgetConfig | None = None,
               acc_table: np.ndarray | None = None) -> RunResult:
    """scheme in {one_time_fixed, best_fixed, best_dynamic, panoptes,
    tracking, ucb1}. Oracle schemes pick (cell, zoom) jointly from the
    flattened 75-orientation table, mirroring §2.2."""
    grid = video.grid
    if acc_table is None:
        acc_table = acc_mod.workload_acc_table(video, workload, tables,
                                               ZOOM_LEVELS)
    T, N, Z = acc_table.shape
    flat = acc_table.reshape(T, N * Z)

    def unflat(idx):
        return (int(idx) // Z, int(idx) % Z)

    stride = 1
    if budget is not None:
        stride = max(1, int(round(video.fps / budget.fps)))
    frames = list(range(0, T, stride))
    sub = flat[frames]

    if scheme == "one_time_fixed":
        choices = bl.one_time_fixed(sub)
        rows = [[unflat(c)] for c in choices]
    elif scheme == "best_fixed":
        ch = bl.best_fixed(sub, k=k)
        if k == 1:
            rows = [[unflat(c)] for c in ch]
        else:
            rows = [[unflat(c) for c in row] for row in ch]
    elif scheme == "best_dynamic":
        choices = bl.best_dynamic(sub)
        rows = [[unflat(c)] for c in choices]
    elif scheme == "panoptes":
        motion = motion_table(video)[frames]
        # Panoptes schedules over cells at best zoom per cell
        best_z = acc_table.mean(0).argmax(-1)          # [N]
        cell_acc = acc_table.mean(-1)[frames]
        choices = bl.panoptes(cell_acc, motion, grid=grid)
        rows = [[(int(c), int(best_z[c]))] for c in choices]
    elif scheme == "tracking":
        sizes, cells = largest_object_table(video)
        home = int(np.argmax(acc_table.mean(0).max(-1)))
        choices = bl.tracking(sizes[frames], cells[frames], home, grid)
        best_z = acc_table.mean(0).argmax(-1)
        rows = [[(int(c), int(best_z[c]))] for c in choices]
    elif scheme == "ucb1":
        choices = bl.ucb1(sub)
        rows = [[unflat(c)] for c in choices]
    else:
        raise ValueError(scheme)

    visited = {t: row for t, row in zip(frames, rows)}
    accuracy = acc_mod.evaluate_selection(video, workload, tables, visited,
                                          ZOOM_LEVELS)
    explored = {t: [c for (c, _) in visited[t]] for t in frames}
    hits = [int(np.argmax(flat[t])) // Z in explored[t] for t in frames]
    return RunResult(accuracy, visited, explored,
                     sum(len(v) for v in visited.values()),
                     float(np.mean([len(v) for v in visited.values()])),
                     float(np.mean(hits)))
