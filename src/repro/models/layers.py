"""Foundational pure-JAX layers.

Models in this framework are (params-pytree, pure function) pairs. Every layer
here follows the convention::

    params = layer_init(key, ...)     # returns a pytree of jnp arrays
    y      = layer_apply(params, x)   # pure function

This keeps sharding fully explicit (each leaf gets a PartitionSpec from
``repro.distributed.sharding``) and avoids any framework dependency.
"""
from __future__ import annotations

import math
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp arrays
DTYPE = jnp.float32  # compute dtype default (bf16 selected per-config)

# REPRO_FULL_UNROLL=1 fully unrolls every lax.scan. Production keeps scans
# (small HLO, fast compiles, XLA overlaps per-layer collectives); the
# roofline dry-run unrolls because XLA's cost_analysis counts a loop body
# ONCE regardless of trip count (verified experimentally) — unrolled
# programs give honest per-step FLOP/byte/collective totals.
_FULL_UNROLL = bool(int(os.environ.get("REPRO_FULL_UNROLL", "0")))
# §Perf knob: disable activation rematerialization (trades HBM residency
# for a full recompute pass of bytes+flops)
NO_REMAT = bool(int(os.environ.get("REPRO_NO_REMAT", "0")))


def scan_unroll() -> bool | int:
    """The `unroll=` argument every lax.scan in this codebase uses."""
    return True if _FULL_UNROLL else 1


# Beyond-paper §Perf optimization: pin activation shardings inside the
# model so GSPMD keeps the batch axis sharded through attention (without
# this it may all-gather the batch and shard heads only — measured 4.2x
# per-device FLOP inflation on LM cells). Enabled by setting
# REPRO_ACT_SHARDING to the comma-separated DP axis names ("data" or
# "pod,data"); empty = paper-faithful baseline behavior (GSPMD decides).
def act_dp_axes() -> tuple | None:
    env = os.environ.get("REPRO_ACT_SHARDING", "")
    if not env:
        return None
    return tuple(env.split(","))


def constrain_act(x: jnp.ndarray, spec_tail: tuple) -> jnp.ndarray:
    """with_sharding_constraint(batch=DP axes, then spec_tail) when the
    REPRO_ACT_SHARDING knob is on and the dims divide; no-op otherwise."""
    dp = act_dp_axes()
    if dp is None:
        return x
    return constrain_spec(x, (dp,) + tuple(spec_tail))


def constrain_spec(x: jnp.ndarray, spec: tuple) -> jnp.ndarray:
    """Raw with_sharding_constraint guarded by the same knob ('data' in a
    spec entry is replaced by the configured DP axes)."""
    dp = act_dp_axes()
    if dp is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = tuple(dp if s == "data" else s for s in spec)
    if len(spec) != x.ndim:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no ambient mesh (plain CPU tests) — no-op
        return x


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, std: float = 0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def lecun_normal(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(1.0 / max(1, fan_in))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def he_normal(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / max(1, fan_in))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Linear / embeddings
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = True,
                std: float | None = None, dtype=jnp.float32) -> Params:
    kw, _ = jax.random.split(key)
    w = (trunc_normal(kw, (d_in, d_out), std=std, dtype=dtype)
         if std is not None
         else lecun_normal(kw, (d_in, d_out), dtype=dtype))
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32) -> Params:
    return {"table": trunc_normal(key, (vocab, dim), std=0.02, dtype=dtype)}


def embedding(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def modulated_layernorm(p: Params, x: jnp.ndarray, shift: jnp.ndarray,
                        scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """adaLN: LayerNorm (no affine) then (1+scale)*x + shift — DiT-style."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32)) + shift.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = False,
             bias: bool = True, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": linear_init(k1, d_model, d_ff, bias=bias, dtype=dtype),
         "down": linear_init(k2, d_ff, d_model, bias=bias, dtype=dtype)}
    if gated:
        p["gate"] = linear_init(k3, d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, *, act: str = "gelu") -> jnp.ndarray:
    h = linear(p["up"], x)
    if "gate" in p:  # SwiGLU-style
        g = linear(p["gate"], x)
        h = jax.nn.silu(g) * h
    else:
        h = _ACT[act](h)
    return linear(p["down"], h)


_ACT: dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


# ---------------------------------------------------------------------------
# Conv (patch embed / detector necks). NHWC layout (TPU-native).
# ---------------------------------------------------------------------------

def conv_init(key, k_h: int, k_w: int, c_in: int, c_out: int, *,
              bias: bool = True, dtype=jnp.float32) -> Params:
    kw, _ = jax.random.split(key)
    fan_in = k_h * k_w * c_in
    p = {"w": he_normal(kw, (k_h, k_w, c_in, c_out), fan_in=fan_in,
                        dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype=dtype)
    return p


def conv2d(p: Params, x: jnp.ndarray, *, stride: int = 1,
           padding: str = "SAME") -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Stacked-layer utilities (scan-over-layers)
# ---------------------------------------------------------------------------

def stack_init(key, n_layers: int,
               init_fn: Callable[[jax.Array], Params]) -> Params:
    """Initialize n_layers copies of a layer and stack leaves on axis 0.

    The result feeds ``jax.lax.scan`` — one compiled layer body regardless of
    depth, which keeps HLO small and lets XLA overlap per-layer collectives.
    """
    keys = jax.random.split(key, n_layers)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def scan_layers(body: Callable, stacked: Params, x, *, extra=None,
                remat: bool = False, remat_policy: str | None = None):
    """Run ``body(layer_params, carry, extra) -> carry`` over stacked
    layers."""
    fn = body
    if remat and NO_REMAT:
        remat = False
    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        elif remat_policy == "dots_no_batch":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        fn = jax.checkpoint(body, policy=policy)

    def step(carry, layer_params):
        return fn(layer_params, carry, extra), None

    y, _ = jax.lax.scan(step, x, stacked, unroll=scan_unroll())
    return y


def count_params(params: Params) -> int:
    return int(sum(p.size for p in jax.tree.leaves(params)))


def param_bytes(params: Params) -> int:
    return int(sum(p.size * p.dtype.itemsize for p in jax.tree.leaves(params)))


def cast_floats(params: Params, dtype) -> Params:
    def c(p):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p
    return jax.tree.map(c, params)
