"""Diffusion training losses + samplers (DDPM for DiT, rectified flow
for Flux).

The denoising loop runs one backbone forward per sampler step — a 50-step
sampler is 50 forwards (per the pool note). `sample_*` wraps the loop in
`lax.fori_loop`/`lax.scan` so the compiled artifact contains the step count.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import DiffusionConfig
from repro.models.dit import dit_forward
from repro.models.mmdit import TXT_TOKENS, mmdit_forward


# ---------------------------------------------------------------------------
# DDPM schedule (DiT): linear beta, epsilon prediction
# ---------------------------------------------------------------------------

def ddpm_schedule(n_steps: int = 1000, beta_0: float = 1e-4,
                  beta_T: float = 0.02):
    betas = jnp.linspace(beta_0, beta_T, n_steps, dtype=jnp.float32)
    alphas = 1.0 - betas
    abar = jnp.cumprod(alphas)
    return {"betas": betas, "alphas": alphas, "alpha_bars": abar}


def dit_train_loss(params, cfg: DiffusionConfig, latents: jnp.ndarray,
                   y: jnp.ndarray, key, *, n_steps: int = 1000):
    """Epsilon-prediction MSE. latents [B,R,R,C] clean; y [B] labels."""
    B = latents.shape[0]
    sched = ddpm_schedule(n_steps)
    kt, ke = jax.random.split(key)
    t = jax.random.randint(kt, (B,), 0, n_steps)
    eps = jax.random.normal(ke, latents.shape, dtype=jnp.float32)
    ab = sched["alpha_bars"][t][:, None, None, None]
    x_t = jnp.sqrt(ab) * latents.astype(jnp.float32) + jnp.sqrt(1 - ab) * eps
    pred = dit_forward(params, cfg, x_t.astype(cfg.dtype),
                       t.astype(jnp.float32), y).astype(jnp.float32)
    return jnp.mean(jnp.square(pred - eps))


def dit_sample(params, cfg: DiffusionConfig, key, *, batch: int,
               n_steps: int = 50, train_steps: int = 1000,
               y: jnp.ndarray | None = None, latent_res: int | None = None):
    """DDIM sampler (eta=0): n_steps forwards. Returns latents [B,R,R,C]."""
    R = latent_res or cfg.latent_res or cfg.img_res // 8
    C = cfg.latent_channels
    sched = ddpm_schedule(train_steps)
    if y is None:
        y = jnp.zeros((batch,), jnp.int32)
    ts = jnp.linspace(train_steps - 1, 0, n_steps).astype(jnp.int32)

    x = jax.random.normal(key, (batch, R, R, C), dtype=jnp.float32)

    def step(x, i):
        t = ts[i]
        t_prev = jnp.where(i + 1 < n_steps,
                           ts[jnp.minimum(i + 1, n_steps - 1)], 0)
        ab_t = sched["alpha_bars"][t]
        ab_p = jnp.where(i + 1 < n_steps, sched["alpha_bars"][t_prev], 1.0)
        eps = dit_forward(params, cfg, x.astype(cfg.dtype),
                          jnp.full((batch,), t, jnp.float32),
                          y).astype(jnp.float32)
        x0 = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
        x = jnp.sqrt(ab_p) * x0 + jnp.sqrt(1 - ab_p) * eps
        return x, None

    from repro.models.layers import scan_unroll
    x, _ = jax.lax.scan(step, x, jnp.arange(n_steps), unroll=scan_unroll())
    return x


# ---------------------------------------------------------------------------
# Rectified flow (Flux): velocity prediction, straight-line paths
# ---------------------------------------------------------------------------

def rf_train_loss(params, cfg: DiffusionConfig, latents: jnp.ndarray,
                  txt_emb: jnp.ndarray, key):
    """Rectified-flow MSE on velocity. latents [B,R,R,C] clean."""
    B = latents.shape[0]
    kt, ke = jax.random.split(key)
    # logit-normal timestep sampling (SD3/Flux practice)
    t = jax.nn.sigmoid(jax.random.normal(kt, (B,)))
    noise = jax.random.normal(ke, latents.shape, dtype=jnp.float32)
    x1 = latents.astype(jnp.float32)
    tb = t[:, None, None, None]
    x_t = (1 - tb) * noise + tb * x1
    target_v = x1 - noise
    pred = mmdit_forward(params, cfg, x_t.astype(cfg.dtype), t,
                         txt_emb).astype(jnp.float32)
    return jnp.mean(jnp.square(pred - target_v))


def rf_sample(params, cfg: DiffusionConfig, key, *, batch: int,
              n_steps: int = 50, txt_emb: jnp.ndarray | None = None,
              latent_res: int | None = None):
    """Euler integration of the learned velocity field: n_steps forwards."""
    R = latent_res or cfg.latent_res or cfg.img_res // 8
    C = cfg.latent_channels
    if txt_emb is None:
        txt_emb = jnp.zeros((batch, TXT_TOKENS, cfg.cond_dim), jnp.float32)
    x = jax.random.normal(key, (batch, R, R, C), dtype=jnp.float32)
    dt = 1.0 / n_steps

    def step(x, i):
        t = i.astype(jnp.float32) * dt
        v = mmdit_forward(params, cfg, x.astype(cfg.dtype),
                          jnp.full((batch,), t, jnp.float32),
                          txt_emb).astype(jnp.float32)
        return x + dt * v, None

    from repro.models.layers import scan_unroll
    x, _ = jax.lax.scan(step, x, jnp.arange(n_steps), unroll=scan_unroll())
    return x
