"""MadEye approximation model — TPU-native EfficientDet-D0 analogue.

Paper §3.1: an ultra-lightweight detector for objects of interest, one per
query, used ONLY to rank orientations. Design choices mirrored here:

  * frozen feature extractor shared across queries (paper: EfficientDet
    backbone + BiFPN frozen, pre-trained on VOC) -> here: a small ViT
    backbone + FPN-lite neck whose params sit under ``params["backbone"]``
    and are excluded from fine-tuning via `lax.stop_gradient` + optimizer
    masking (train/optim.py);
  * only the final box/class/centerness heads are per-query fine-tuned
    (paper: "only weights for the final 3 bounding box and class prediction
    layers");
  * static box budget (max_boxes) — no dynamic shapes on TPU; outputs carry
    a validity score instead of being pruned by NMS-with-dynamic-output.

Output format (per image): boxes [max_boxes, 4] in [0,1] cxcywh,
scores [max_boxes], class_probs [max_boxes, n_classes].
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import DetectorConfig, VisionConfig
from repro.models import vit
from repro.models.layers import Params, conv2d, conv_init


class Detections(NamedTuple):
    boxes: jnp.ndarray        # [..., max_boxes, 4] cxcywh in [0, 1]
    scores: jnp.ndarray       # [..., max_boxes] objectness * class prob
    class_probs: jnp.ndarray  # [..., max_boxes, n_classes]


def _backbone_cfg(cfg: DetectorConfig) -> VisionConfig:
    return VisionConfig(
        name=f"{cfg.name}-backbone", img_res=cfg.img_res, patch=cfg.patch,
        n_layers=cfg.n_layers, d_model=cfg.d_model, n_heads=cfg.n_heads,
        d_ff=cfg.d_ff, n_classes=2, dtype=cfg.dtype)


def detector_init(key, cfg: DetectorConfig) -> Params:
    kb, kn, kh1, kh2, kh3 = jax.random.split(key, 5)
    bcfg = _backbone_cfg(cfg)
    F = cfg.fpn_dim
    return {
        # ---- frozen across queries (cached on cameras) ----
        "backbone": {
            "vit": vit.vit_init(kb, bcfg),
            "neck": {
                "lateral": conv_init(jax.random.fold_in(kn, 0), 1, 1,
                                     cfg.d_model, F, dtype=cfg.dtype),
                "smooth": conv_init(jax.random.fold_in(kn, 1), 3, 3, F, F,
                                    dtype=cfg.dtype),
            },
        },
        # ---- fine-tuned per query (paper: final 3 prediction layers) ----
        "heads": {
            "cls": conv_init(kh1, 3, 3, F, cfg.n_classes, dtype=cfg.dtype),
            "box": conv_init(kh2, 3, 3, F, 4, dtype=cfg.dtype),
            "obj": conv_init(kh3, 3, 3, F, 1, dtype=cfg.dtype),
        },
    }


def neck_features(bb: Params, feats: jnp.ndarray) -> jnp.ndarray:
    """backbone feature map [B, g, g, D] -> post-neck map [B, g, g, F].

    The frozen end of the network: everything up to (and including) this
    is masked out of fine-tuning, which is what lets the in-scan learner
    (repro.learn) stage these features once from the inference forward
    and train the heads on them without re-running the backbone."""
    f = conv2d(bb["neck"]["lateral"], feats)
    return jax.nn.gelu(conv2d(bb["neck"]["smooth"], f))     # [B, g, g, F]


def head_outputs(heads: Params, f: jnp.ndarray):
    """post-neck features [B, g, g, F] -> raw head outputs — the
    per-query fine-tuned slice of the forward (paper: the final 3
    prediction layers)."""
    cls_logits = conv2d(heads["cls"], f)
    box_raw = conv2d(heads["box"], f)
    obj_logits = conv2d(heads["obj"], f)[..., 0]
    return cls_logits, box_raw, obj_logits


def _neck_and_heads(params: Params, bb: Params, feats: jnp.ndarray):
    """backbone feature map [B, g, g, D] -> raw head outputs."""
    return head_outputs(params["heads"], neck_features(bb, feats))


def detector_raw(params: Params, cfg: DetectorConfig, images: jnp.ndarray, *,
                 freeze_backbone: bool = False):
    """images [B,H,W,3] -> (cls_logits [B,g,g,K], box [B,g,g,4], obj [B,g,g]).

    Box parametrization: sigmoid(dx,dy) = center offset inside the cell,
    sigmoid(w,h) = size relative to the whole image.
    """
    bcfg = _backbone_cfg(cfg)
    bb = params["backbone"]
    if freeze_backbone:
        bb = jax.lax.stop_gradient(bb)
    feats = vit.vit_features(bb["vit"], bcfg, images)      # [B, g, g, D]
    return _neck_and_heads(params, bb, feats)


def detector_raw_tokens(params: Params, cfg: DetectorConfig,
                        tokens: jnp.ndarray, *,
                        freeze_backbone: bool = False):
    """Patch-embedding tokens [B, P, D] (vit.vit_embed layout — e.g. the
    fused kernels/crop_patchify output) -> the same raw head outputs as
    `detector_raw` on the images those tokens embed."""
    bcfg = _backbone_cfg(cfg)
    bb = params["backbone"]
    if freeze_backbone:
        bb = jax.lax.stop_gradient(bb)
    feats = vit.vit_features_tokens(bb["vit"], bcfg, tokens)
    return _neck_and_heads(params, bb, feats)


def detector_neck_feats_tokens(params: Params, cfg: DetectorConfig,
                               tokens: jnp.ndarray) -> jnp.ndarray:
    """Patch tokens [B, P, D] -> post-neck feature map [B, g, g, F].

    The frozen half of the fused fast path when the heads are trained
    per camera (repro.learn): the shared backbone+neck run once over
    the [F*K] shortlist, per-camera heads consume the result, and the
    same features are staged as the training payload — head-only
    distillation re-runs zero backbone compute."""
    bcfg = _backbone_cfg(cfg)
    bb = params["backbone"]
    feats = vit.vit_features_tokens(bb["vit"], bcfg, tokens)
    return neck_features(bb, feats)


def detections_from_feats(cfg: DetectorConfig, heads: Params,
                          feats: jnp.ndarray) -> Detections:
    """Post-neck features [B, g, g, F] + head params -> Detections.
    Completes `detector_neck_feats_tokens` with (possibly per-camera
    fine-tuned) heads."""
    return _decode_detections(cfg, *head_outputs(heads, feats))


def decode_boxes(box_raw: jnp.ndarray) -> jnp.ndarray:
    """[B,g,g,4] raw -> cxcywh in [0,1] (cell-relative center + global
    size)."""
    B, g = box_raw.shape[0], box_raw.shape[1]
    ys, xs = jnp.meshgrid(jnp.arange(g), jnp.arange(g), indexing="ij")
    off = jax.nn.sigmoid(box_raw[..., :2])
    cx = (xs[None] + off[..., 0]) / g
    cy = (ys[None] + off[..., 1]) / g
    wh = jax.nn.sigmoid(box_raw[..., 2:])
    return jnp.stack([cx, cy, wh[..., 0], wh[..., 1]], axis=-1)


def detector_forward(params: Params, cfg: DetectorConfig,
                     images: jnp.ndarray) -> Detections:
    """images [B,H,W,3] -> top-`max_boxes` Detections per image."""
    return _decode_detections(cfg, *detector_raw(params, cfg, images))


def detector_forward_tokens(params: Params, cfg: DetectorConfig,
                            tokens: jnp.ndarray) -> Detections:
    """Patch tokens [B, P, D] -> top-`max_boxes` Detections per crop —
    the single batched forward of the candidate-sparse fast path
    (fleet.DetectorProvider flattens [F, K] -> [F*K] rows)."""
    return _decode_detections(cfg,
                              *detector_raw_tokens(params, cfg, tokens))


def _decode_detections(cfg: DetectorConfig, cls_logits, box_raw,
                       obj_logits) -> Detections:
    B, g = cls_logits.shape[0], cls_logits.shape[1]
    boxes = decode_boxes(box_raw).reshape(B, g * g, 4)
    cls_probs = jax.nn.softmax(
        cls_logits.reshape(B, g * g, -1).astype(jnp.float32), axis=-1)
    obj = jax.nn.sigmoid(obj_logits.reshape(B, g * g).astype(jnp.float32))
    scores = obj * jnp.max(cls_probs, axis=-1)

    k = min(cfg.max_boxes, g * g)
    top_scores, idx = jax.lax.top_k(scores, k)
    top_boxes = jnp.take_along_axis(boxes, idx[..., None], axis=1)
    top_probs = jnp.take_along_axis(cls_probs, idx[..., None], axis=1)
    pad = cfg.max_boxes - k
    if pad > 0:
        top_scores = jnp.pad(top_scores, ((0, 0), (0, pad)))
        top_boxes = jnp.pad(top_boxes, ((0, 0), (0, pad), (0, 0)))
        top_probs = jnp.pad(top_probs, ((0, 0), (0, pad), (0, 0)))
    return Detections(top_boxes, top_scores, top_probs)


# ---------------------------------------------------------------------------
# Training loss (distillation target = teacher boxes; see core/distill.py)
# ---------------------------------------------------------------------------

def detector_loss_from_outputs(cls_logits: jnp.ndarray, box_raw: jnp.ndarray,
                               obj_logits: jnp.ndarray,
                               gt_boxes: jnp.ndarray, gt_classes: jnp.ndarray,
                               gt_valid: jnp.ndarray,
                               weight: jnp.ndarray | None = None):
    """The anchor-free single-level loss on raw head outputs.

    The ONE loss definition: `detector_loss` (full forward) and the
    in-scan distillation objective (repro.learn.loss, staged post-neck
    features) both reduce to this. `weight` [B] is an optional per-sample
    weight — the pair-buffer path weighs empty ring slots 0 so idle
    buffer rows contribute nothing; weight=None is the exact unweighted
    math (bit-identical to the pre-refactor loss).
    """
    B, g = cls_logits.shape[0], cls_logits.shape[1]
    K = cls_logits.shape[-1]

    # Assign GT to cells: cell index of each GT center
    cx, cy = gt_boxes[..., 0], gt_boxes[..., 1]
    ci = jnp.clip((cx * g).astype(jnp.int32), 0, g - 1)
    cj = jnp.clip((cy * g).astype(jnp.int32), 0, g - 1)
    cell = cj * g + ci                                   # [B, N]

    # Build dense targets [B, g*g, ...] via scatter (last valid GT wins).
    obj_t = jnp.zeros((B, g * g))
    cls_t = jnp.zeros((B, g * g), jnp.int32)
    box_t = jnp.zeros((B, g * g, 4))

    bidx = jnp.arange(B)[:, None].repeat(gt_boxes.shape[1], 1)
    v = gt_valid.astype(jnp.float32)
    safe_cell = jnp.where(gt_valid, cell, 0)
    obj_t = obj_t.at[bidx, safe_cell].max(v)
    cls_t = cls_t.at[bidx, safe_cell].set(
        jnp.where(gt_valid, gt_classes, cls_t[bidx, safe_cell]))
    box_t = box_t.at[bidx, safe_cell].set(
        jnp.where(gt_valid[..., None], gt_boxes, box_t[bidx, safe_cell]))

    obj_logits = obj_logits.reshape(B, g * g).astype(jnp.float32)
    cls_logits = cls_logits.reshape(B, g * g, K).astype(jnp.float32)
    pred_boxes = decode_boxes(box_raw).reshape(B, g * g, 4)

    # focal-style objectness BCE
    p = jax.nn.sigmoid(obj_logits)
    bce = -(obj_t * jnp.log(p + 1e-8) + (1 - obj_t) * jnp.log(1 - p + 1e-8))
    focal_w = jnp.where(obj_t > 0, (1 - p) ** 2, p ** 2)
    pos = obj_t                                          # [B, g*g]
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    cls_nll = -jnp.take_along_axis(logp, cls_t[..., None], axis=-1)[..., 0]
    box_l1 = jnp.abs(pred_boxes - box_t)

    if weight is None:
        obj_loss = jnp.mean(focal_w * bce)
        n_pos = jnp.maximum(jnp.sum(pos), 1.0)
        cls_loss = jnp.sum(pos * cls_nll) / n_pos
        box_loss = jnp.sum(pos[..., None] * box_l1) / n_pos
    else:
        w = weight.astype(jnp.float32)[:, None]          # [B, 1]
        obj_loss = (jnp.sum(w * focal_w * bce)
                    / jnp.maximum(jnp.sum(w) * (g * g), 1.0))
        wpos = w * pos
        n_pos = jnp.maximum(jnp.sum(wpos), 1.0)
        cls_loss = jnp.sum(wpos * cls_nll) / n_pos
        box_loss = jnp.sum(wpos[..., None] * box_l1) / n_pos

    return obj_loss + cls_loss + box_loss


def detector_loss(params: Params, cfg: DetectorConfig, images: jnp.ndarray,
                  gt_boxes: jnp.ndarray, gt_classes: jnp.ndarray,
                  gt_valid: jnp.ndarray, *, freeze_backbone: bool = True):
    """Anchor-free single-level loss over a full image forward.

    gt_boxes [B,N,4] cxcywh; gt_classes [B,N] int; gt_valid [B,N] bool.
    Each valid GT is assigned to the cell containing its center.
    """
    cls_logits, box_raw, obj_logits = detector_raw(
        params, cfg, images, freeze_backbone=freeze_backbone)
    return detector_loss_from_outputs(cls_logits, box_raw, obj_logits,
                                      gt_boxes, gt_classes, gt_valid)


def detector_loss_tokens(params: Params, cfg: DetectorConfig,
                         tokens: jnp.ndarray, gt_boxes: jnp.ndarray,
                         gt_classes: jnp.ndarray, gt_valid: jnp.ndarray, *,
                         weight: jnp.ndarray | None = None,
                         freeze_backbone: bool = False):
    """`detector_loss` starting from patch-embedding tokens [B, P, D] —
    the full-param distillation objective (the staged training payload
    is the crop_patchify token buffer, re-run through the trainable
    backbone)."""
    cls_logits, box_raw, obj_logits = detector_raw_tokens(
        params, cfg, tokens, freeze_backbone=freeze_backbone)
    return detector_loss_from_outputs(cls_logits, box_raw, obj_logits,
                                      gt_boxes, gt_classes, gt_valid,
                                      weight=weight)


def head_params_mask(params: Params) -> Params:
    """Pytree mask: True for fine-tuned (head) leaves, False for backbone."""
    return jax.tree.map(lambda _: False, params) | {
        "heads": jax.tree.map(lambda _: True, params["heads"])}
