"""Flux-dev style MMDiT — 19 double-stream + 38 single-stream blocks,
rectified-flow objective. Pure JAX, scan-over-layers per block family.

Double blocks: image and text streams each get their own QKV/MLP and adaLN
modulation, attention runs over the concatenated token sequence.
Single blocks: fused stream with parallel attention+MLP (Flux style).

Text conditioning is a STUB input (precomputed embeddings [B, T_txt, cond_dim])
per the pool's instructions for modality frontends.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import DiffusionConfig
from repro.models import attention as attn
from repro.models.dit import timestep_embedding
from repro.models.layers import (
    Params,
    linear,
    linear_init,
    modulated_layernorm,
    rmsnorm,
    rmsnorm_init,
    scan_layers,
    stack_init,
)

TXT_TOKENS = 128  # stub text-sequence length


def _qkv_init(key, D, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": linear_init(kq, D, D, bias=True, dtype=dtype),
        "wk": linear_init(kk, D, D, bias=True, dtype=dtype),
        "wv": linear_init(kv, D, D, bias=True, dtype=dtype),
        "wo": linear_init(ko, D, D, bias=True, dtype=dtype),
        "q_norm": rmsnorm_init(D, dtype=dtype),
        "k_norm": rmsnorm_init(D, dtype=dtype),
    }


def _mlp_init(key, D, dtype):
    k1, k2 = jax.random.split(key)
    return {"up": linear_init(k1, D, 4 * D, bias=True, dtype=dtype),
            "down": linear_init(k2, 4 * D, D, bias=True, dtype=dtype)}


def double_block_init(key, cfg: DiffusionConfig) -> Params:
    D = cfg.d_model
    keys = jax.random.split(key, 6)
    return {
        "img_attn": _qkv_init(keys[0], D, cfg.dtype),
        "txt_attn": _qkv_init(keys[1], D, cfg.dtype),
        "img_mlp": _mlp_init(keys[2], D, cfg.dtype),
        "txt_mlp": _mlp_init(keys[3], D, cfg.dtype),
        "img_ada": {"w": jnp.zeros((D, 6 * D), cfg.dtype),
                    "b": jnp.zeros((6 * D,), cfg.dtype)},
        "txt_ada": {"w": jnp.zeros((D, 6 * D), cfg.dtype),
                    "b": jnp.zeros((6 * D,), cfg.dtype)},
    }


def single_block_init(key, cfg: DiffusionConfig) -> Params:
    D = cfg.d_model
    keys = jax.random.split(key, 4)
    return {
        "attn": _qkv_init(keys[0], D, cfg.dtype),
        "mlp": _mlp_init(keys[1], D, cfg.dtype),
        "ada": {"w": jnp.zeros((D, 3 * D), cfg.dtype),
                "b": jnp.zeros((3 * D,), cfg.dtype)},
    }


def _heads(x, n_heads):
    B, T, D = x.shape
    return x.reshape(B, T, n_heads, D // n_heads)


def sincos_2d(g: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    """[1, g*g, dim] fixed axial sin-cos position embedding (Flux encodes
    position with RoPE; a fixed additive embedding is the parameter-free
    stand-in that keeps tokens position-aware)."""
    half = dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half // 2) / max(half // 2, 1)))
    ys, xs = jnp.meshgrid(jnp.arange(g, dtype=jnp.float32),
                          jnp.arange(g, dtype=jnp.float32), indexing="ij")

    def axis(v):
        a = v.reshape(-1)[:, None] * freqs[None]
        return jnp.concatenate([jnp.sin(a), jnp.cos(a)], axis=-1)

    emb = jnp.concatenate([axis(ys), axis(xs)], axis=-1)
    if emb.shape[-1] < dim:
        emb = jnp.pad(emb, ((0, 0), (0, dim - emb.shape[-1])))
    return emb[None].astype(dtype)


def _qk_norm(p, q, k):
    return rmsnorm(p["q_norm"], q), rmsnorm(p["k_norm"], k)


def double_block(p: Params, img: jnp.ndarray, txt: jnp.ndarray,
                 c: jnp.ndarray, cfg: DiffusionConfig):
    """img [B,Ti,D], txt [B,Tt,D], c [B,D] -> (img', txt')."""
    H = cfg.n_heads
    im = linear(p["img_ada"], jax.nn.silu(c))[:, None, :]
    tm = linear(p["txt_ada"], jax.nn.silu(c))[:, None, :]
    ish1, isc1, ig1, ish2, isc2, ig2 = jnp.split(im, 6, axis=-1)
    tsh1, tsc1, tg1, tsh2, tsc2, tg2 = jnp.split(tm, 6, axis=-1)

    hi = modulated_layernorm({}, img, ish1, isc1)
    ht = modulated_layernorm({}, txt, tsh1, tsc1)

    qi = linear(p["img_attn"]["wq"], hi)
    ki = linear(p["img_attn"]["wk"], hi)
    vi = linear(p["img_attn"]["wv"], hi)
    qi, ki = _qk_norm(p["img_attn"], qi, ki)
    qt = linear(p["txt_attn"]["wq"], ht)
    kt = linear(p["txt_attn"]["wk"], ht)
    vt = linear(p["txt_attn"]["wv"], ht)
    qt, kt = _qk_norm(p["txt_attn"], qt, kt)

    Tt = txt.shape[1]
    q = _heads(jnp.concatenate([qt, qi], axis=1), H)
    k = _heads(jnp.concatenate([kt, ki], axis=1), H)
    v = _heads(jnp.concatenate([vt, vi], axis=1), H)
    o = attn.sdpa(q, k, v, causal=False, impl="xla")
    o = o.reshape(o.shape[0], o.shape[1], -1)
    ot, oi = o[:, :Tt], o[:, Tt:]

    img = img + ig1 * linear(p["img_attn"]["wo"], oi)
    txt = txt + tg1 * linear(p["txt_attn"]["wo"], ot)

    hi = modulated_layernorm({}, img, ish2, isc2)
    img = img + ig2 * linear(p["img_mlp"]["down"],
                             jax.nn.gelu(linear(p["img_mlp"]["up"], hi)))
    ht = modulated_layernorm({}, txt, tsh2, tsc2)
    txt = txt + tg2 * linear(p["txt_mlp"]["down"],
                             jax.nn.gelu(linear(p["txt_mlp"]["up"], ht)))
    return img, txt


def single_block(p: Params, x: jnp.ndarray, c: jnp.ndarray,
                 cfg: DiffusionConfig) -> jnp.ndarray:
    """Fused stream [B,T,D]: parallel attention + MLP (Flux single block)."""
    H = cfg.n_heads
    mod = linear(p["ada"], jax.nn.silu(c))[:, None, :]
    sh, sc, g = jnp.split(mod, 3, axis=-1)
    h = modulated_layernorm({}, x, sh, sc)
    q = linear(p["attn"]["wq"], h)
    k = linear(p["attn"]["wk"], h)
    v = linear(p["attn"]["wv"], h)
    q, k = _qk_norm(p["attn"], q, k)
    o = attn.sdpa(_heads(q, H), _heads(k, H), _heads(v, H), causal=False,
                  impl="xla")
    o = linear(p["attn"]["wo"], o.reshape(x.shape))
    m = linear(p["mlp"]["down"], jax.nn.gelu(linear(p["mlp"]["up"], h)))
    return x + g * (o + m)


def mmdit_init(key, cfg: DiffusionConfig) -> Params:
    D = cfg.d_model
    C = cfg.latent_channels
    keys = jax.random.split(key, 10)
    return {
        "img_in": linear_init(keys[0], cfg.patch * cfg.patch * C, D,
                              dtype=cfg.dtype),
        "txt_in": linear_init(keys[1], cfg.cond_dim, D, dtype=cfg.dtype),
        "t_mlp": {
            "fc1": linear_init(keys[2], 256, D, dtype=cfg.dtype),
            "fc2": linear_init(keys[3], D, D, dtype=cfg.dtype),
        },
        "double": stack_init(keys[4], cfg.n_double_blocks,
                             lambda k: double_block_init(k, cfg)),
        "single": stack_init(keys[5], cfg.n_single_blocks,
                             lambda k: single_block_init(k, cfg)),
        "final_ada": {"w": jnp.zeros((D, 2 * D), cfg.dtype),
                      "b": jnp.zeros((2 * D,), cfg.dtype)},
        "final_proj": linear_init(keys[6], D, cfg.patch * cfg.patch * C,
                                  std=0.0, dtype=cfg.dtype),
    }


def mmdit_forward(params: Params, cfg: DiffusionConfig, latents: jnp.ndarray,
                  t: jnp.ndarray, txt_emb: jnp.ndarray) -> jnp.ndarray:
    """latents [B,R,R,C]; t [B] in [0,1]; txt_emb [B,T_txt,cond_dim]."""
    B, R, _, C = latents.shape
    p_sz = cfg.patch
    g = R // p_sz

    x = latents.reshape(B, g, p_sz, g, p_sz, C).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(B, g * g, p_sz * p_sz * C)
    img = linear(params["img_in"], x.astype(cfg.dtype))
    img = img + sincos_2d(g, cfg.d_model, img.dtype)
    txt = linear(params["txt_in"], txt_emb.astype(cfg.dtype))

    t_emb = timestep_embedding(t * 1000.0, 256)
    c = linear(params["t_mlp"]["fc2"],
               jax.nn.silu(linear(params["t_mlp"]["fc1"],
                                  t_emb.astype(cfg.dtype))))

    def dbody(lp, carry, extra):
        img, txt = carry
        img, txt = double_block(lp, img, txt, extra, cfg)
        return (img, txt)

    img, txt = scan_layers(dbody, params["double"], (img, txt), extra=c,
                           remat=cfg.remat, remat_policy="dots_no_batch")

    fused = jnp.concatenate([txt, img], axis=1)

    def sbody(lp, carry, extra):
        return single_block(lp, carry, extra, cfg)

    fused = scan_layers(sbody, params["single"], fused, extra=c,
                        remat=cfg.remat, remat_policy="dots_no_batch")
    img = fused[:, txt.shape[1]:]

    mod = linear(params["final_ada"], jax.nn.silu(c))
    sh, sc = jnp.split(mod[:, None, :], 2, axis=-1)
    img = modulated_layernorm({}, img, sh, sc)
    out = linear(params["final_proj"], img)           # [B, g*g, p*p*C]
    out = out.reshape(B, g, g, p_sz, p_sz, C)
    out = out.transpose(0, 1, 3, 2, 4, 5).reshape(B, R, R, C)
    return out
