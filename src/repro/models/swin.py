"""Swin Transformer (Swin-B) — windowed + shifted-window attention,
patch merging between stages. Pure JAX; stages are Python loops (hetero
dims), blocks within a stage run under scan where the stage is deep.

Layout: NHWC feature maps between stages; windows flattened for attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VisionConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    conv2d,
    conv_init,
    layernorm,
    layernorm_init,
    linear,
    linear_init,
    mlp,
    mlp_init,
    trunc_normal,
)


def _rel_position_index(window: int) -> np.ndarray:
    """[w^2, w^2] index into the (2w-1)^2 relative-bias table."""
    coords = np.stack(np.meshgrid(np.arange(window), np.arange(window),
                                  indexing="ij"))  # [2, w, w]
    flat = coords.reshape(2, -1)                    # [2, w^2]
    rel = flat[:, :, None] - flat[:, None, :]       # [2, w^2, w^2]
    rel = rel.transpose(1, 2, 0) + (window - 1)
    return rel[..., 0] * (2 * window - 1) + rel[..., 1]


MAX_WINDOW = 12  # rel-bias tables sized for the largest window (384-res)


def _effective_window(map_size: int, preferred: int) -> int:
    """Largest window <= MAX_WINDOW that divides the feature map (Swin-384
    uses window 12 where 7 does not divide the 96x96 stage-1 map)."""
    if map_size % preferred == 0:
        return preferred
    for w in range(min(MAX_WINDOW, map_size), 0, -1):
        if map_size % w == 0:
            return w
    return 1


def swin_block_init(key, dim: int, n_heads: int, window: int,
                    mlp_ratio: float = 4.0, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    n_bias = (2 * max(window, MAX_WINDOW) - 1) ** 2
    return {
        "norm1": layernorm_init(dim, dtype=dtype),
        "attn": {
            "wq": linear_init(jax.random.fold_in(k1, 0), dim, dim,
                              dtype=dtype),
            "wk": linear_init(jax.random.fold_in(k1, 1), dim, dim,
                              dtype=dtype),
            "wv": linear_init(jax.random.fold_in(k1, 2), dim, dim,
                              dtype=dtype),
            "wo": linear_init(jax.random.fold_in(k1, 3), dim, dim,
                              dtype=dtype),
        },
        "rel_bias": trunc_normal(k3, (n_bias, n_heads), dtype=dtype),
        "norm2": layernorm_init(dim, dtype=dtype),
        "mlp": mlp_init(k2, dim, int(dim * mlp_ratio), dtype=dtype),
    }


def swin_block(p: Params, x: jnp.ndarray, *, n_heads: int, window: int,
               shift: int, rel_index: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, W, C]."""
    B, H, W, C = x.shape
    shortcut = x
    x = layernorm(p["norm1"], x)
    if shift > 0:
        x = jnp.roll(x, (-shift, -shift), axis=(1, 2))
    wins = attn.window_partition(x, window)         # [B*nW, w^2, C]

    T = window * window
    rel_bias = p["rel_bias"][rel_index.reshape(-1)].reshape(T, T, -1)
    rel_bias = rel_bias.transpose(2, 0, 1)          # [heads, T, T]
    mask = (attn.shifted_window_mask(H, W, window, shift)
            if shift > 0 else None)
    wins = attn.window_attention(p["attn"], wins, n_heads=n_heads,
                                 rel_bias=rel_bias, mask=mask)
    x = attn.window_unpartition(wins, window, H, W)
    if shift > 0:
        x = jnp.roll(x, (shift, shift), axis=(1, 2))
    x = shortcut + x
    x = x + mlp(p["mlp"], layernorm(p["norm2"], x))
    return x


def patch_merge_init(key, dim: int, dtype=jnp.float32) -> Params:
    return {
        "norm": layernorm_init(4 * dim, dtype=dtype),
        "reduce": linear_init(key, 4 * dim, 2 * dim, bias=False, dtype=dtype),
    }


def patch_merge(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """[B, H, W, C] -> [B, H/2, W/2, 2C]."""
    B, H, W, C = x.shape
    x = x.reshape(B, H // 2, 2, W // 2, 2, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, H // 2, W // 2, 4 * C)
    return linear(p["reduce"], layernorm(p["norm"], x))


def swin_init(key, cfg: VisionConfig) -> Params:
    assert cfg.swin
    depths, dims = cfg.depths, cfg.dims
    keys = jax.random.split(key, len(depths) + 3)
    heads = [max(1, d // 32) for d in dims]
    stages = []
    for s, (depth, dim) in enumerate(zip(depths, dims)):
        bkeys = jax.random.split(keys[s], depth)
        blocks = [swin_block_init(bk, dim, heads[s], cfg.window,
                                  dtype=cfg.dtype) for bk in bkeys]
        stage = {"blocks": blocks}
        if s < len(depths) - 1:
            stage["merge"] = patch_merge_init(
                jax.random.fold_in(keys[s], 999), dim, dtype=cfg.dtype)
        stages.append(stage)
    return {
        "patch_embed": conv_init(keys[-3], cfg.patch, cfg.patch, 3, dims[0],
                                 dtype=cfg.dtype),
        "patch_norm": layernorm_init(dims[0], dtype=cfg.dtype),
        "stages": stages,
        "final_norm": layernorm_init(dims[-1], dtype=cfg.dtype),
        "head": linear_init(keys[-1], dims[-1], cfg.n_classes,
                            dtype=cfg.dtype),
    }


def swin_forward(params: Params, cfg: VisionConfig,
                 images: jnp.ndarray) -> jnp.ndarray:
    """images [B,H,W,3] -> logits [B, n_classes]."""
    depths, dims = cfg.depths, cfg.dims
    heads = [max(1, d // 32) for d in dims]
    w = cfg.window

    x = conv2d(params["patch_embed"], images.astype(cfg.dtype),
               stride=cfg.patch, padding="VALID")
    x = layernorm(params["patch_norm"], x)

    for s, stage in enumerate(params["stages"]):
        for b, bp in enumerate(stage["blocks"]):
            eff_w = _effective_window(x.shape[1], w)
            shift = 0 if (b % 2 == 0 or x.shape[1] <= eff_w) else eff_w // 2
            rel_index = jnp.asarray(_rel_position_index(eff_w))
            x = swin_block(bp, x, n_heads=heads[s], window=eff_w,
                           shift=shift, rel_index=rel_index)
        if "merge" in stage:
            x = patch_merge(stage["merge"], x)

    x = layernorm(params["final_norm"], x)
    x = jnp.mean(x, axis=(1, 2))                    # global average pool
    return linear(params["head"], x)


def swin_loss(params: Params, cfg: VisionConfig, images, labels):
    logits = swin_forward(params, cfg, images).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)
    return jnp.mean(nll)
