"""ViT (S/16, B/16, H/14) — pure JAX, scan-over-layers.

Patch-embed is part of the model (vision pool semantics). Classification uses
a CLS token + linear head; `vit_features` exposes the patch-token feature map
for the detector neck (repro.models.detector).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    conv2d,
    conv_init,
    layernorm,
    layernorm_init,
    linear,
    linear_init,
    mlp,
    mlp_init,
    scan_layers,
    stack_init,
    trunc_normal,
)


def vit_block_init(key, cfg: VisionConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layernorm_init(cfg.d_model, dtype=cfg.dtype),
        "attn": attn.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_heads,
                              bias=True, dtype=cfg.dtype),
        "norm2": layernorm_init(cfg.d_model, dtype=cfg.dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, gated=False, bias=True,
                        dtype=cfg.dtype),
    }


def vit_block(p: Params, x: jnp.ndarray, cfg: VisionConfig,
              impl: str = "xla") -> jnp.ndarray:
    h = attn.gqa_attention(p["attn"], layernorm(p["norm1"], x),
                           n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                           angles=None, causal=False, impl=impl)
    x = x + h
    x = x + mlp(p["mlp"], layernorm(p["norm2"], x))
    return x


def vit_init(key, cfg: VisionConfig, *, img_res: int | None = None) -> Params:
    img_res = img_res or cfg.img_res
    n_patches = (img_res // cfg.patch) ** 2
    kp, kc, kl, kh, kq = jax.random.split(key, 5)
    return {
        "patch_embed": conv_init(kp, cfg.patch, cfg.patch, 3, cfg.d_model,
                                 dtype=cfg.dtype),
        "cls_token": trunc_normal(kc, (1, 1, cfg.d_model), dtype=cfg.dtype),
        "pos_embed": trunc_normal(kq, (1, n_patches + 1, cfg.d_model),
                                  dtype=cfg.dtype),
        "layers": stack_init(kl, cfg.n_layers,
                             lambda k: vit_block_init(k, cfg)),
        "final_norm": layernorm_init(cfg.d_model, dtype=cfg.dtype),
        "head": linear_init(kh, cfg.d_model, cfg.n_classes, dtype=cfg.dtype),
    }


def _interp_pos_embed(pos: jnp.ndarray, n_patches: int) -> jnp.ndarray:
    """Bilinear-resize the grid part of pos_embed to a new patch count."""
    n_old = pos.shape[1] - 1
    if n_old == n_patches:
        return pos
    cls, grid = pos[:, :1], pos[:, 1:]
    g_old = int(round(n_old ** 0.5))
    g_new = int(round(n_patches ** 0.5))
    grid = grid.reshape(1, g_old, g_old, -1)
    grid = jax.image.resize(grid, (1, g_new, g_new, grid.shape[-1]),
                            "bilinear")
    return jnp.concatenate([cls, grid.reshape(1, g_new * g_new, -1)], axis=1)


def vit_embed(params: Params, cfg: VisionConfig,
              images: jnp.ndarray) -> jnp.ndarray:
    """images [B,H,W,3] -> patch-embedding tokens [B, P, D] (no CLS).

    The conv patch-embed in isolation — the seam the fused
    `kernels/crop_patchify` path slots into: anything that produces the
    same [B, P, D] tokens (e.g. rasterizing crops directly into patch
    embeddings) can feed `vit_encode_tokens` without materializing
    pixels."""
    B = images.shape[0]
    x = conv2d(params["patch_embed"], images.astype(cfg.dtype),
               stride=cfg.patch, padding="VALID")           # [B, h, w, D]
    return x.reshape(B, -1, cfg.d_model)


def vit_encode_tokens(params: Params, cfg: VisionConfig, x: jnp.ndarray, *,
                      impl: str = "xla") -> jnp.ndarray:
    """patch tokens [B, P, D] -> encoded tokens [B, 1+P, D] (CLS first).

    The encoder tail of `vit_encode` after the conv patch-embed —
    op-for-op identical, so image-fed and token-fed entries produce the
    same floats for the same patch embeddings."""
    B = x.shape[0]
    cls = jnp.broadcast_to(params["cls_token"].astype(x.dtype),
                           (B, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + _interp_pos_embed(params["pos_embed"],
                              x.shape[1] - 1).astype(x.dtype)

    def body(lp, carry, extra):
        return vit_block(lp, carry, cfg, impl)

    x = scan_layers(body, params["layers"], x, remat=cfg.remat,
                    remat_policy="dots_no_batch")
    return layernorm(params["final_norm"], x)


def vit_encode(params: Params, cfg: VisionConfig, images: jnp.ndarray, *,
               impl: str = "xla") -> jnp.ndarray:
    """images [B,H,W,3] -> tokens [B, 1+P, D] (CLS first)."""
    return vit_encode_tokens(params, cfg, vit_embed(params, cfg, images),
                             impl=impl)


def vit_forward(params: Params, cfg: VisionConfig, images: jnp.ndarray, *,
                impl: str = "xla") -> jnp.ndarray:
    """images [B,H,W,3] -> class logits [B, n_classes]."""
    tokens = vit_encode(params, cfg, images, impl=impl)
    return linear(params["head"], tokens[:, 0])


def vit_features(params: Params, cfg: VisionConfig, images: jnp.ndarray, *,
                 impl: str = "xla") -> jnp.ndarray:
    """images [B,H,W,3] -> patch feature map [B, h, w, D] (no CLS)."""
    return vit_features_tokens(params, cfg,
                               vit_embed(params, cfg, images), impl=impl)


def vit_features_tokens(params: Params, cfg: VisionConfig,
                        tokens: jnp.ndarray, *,
                        impl: str = "xla") -> jnp.ndarray:
    """patch tokens [B, P, D] (square P) -> feature map [B, g, g, D]."""
    B, P = tokens.shape[0], tokens.shape[1]
    g = int(round(P ** 0.5))
    x = vit_encode_tokens(params, cfg, tokens, impl=impl)
    return x[:, 1:].reshape(B, g, g, cfg.d_model)


def vit_loss(params: Params, cfg: VisionConfig, images: jnp.ndarray,
             labels: jnp.ndarray, *, label_smoothing: float = 0.0):
    logits = vit_forward(params, cfg, images).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    n = cfg.n_classes
    onehot = jax.nn.one_hot(labels, n)
    if label_smoothing > 0:
        onehot = onehot * (1 - label_smoothing) + label_smoothing / n
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))
