"""Attention: MHA / GQA / MLA, RoPE, windowed attention.

All attention entry points take ``impl``:
  - "xla":   pure-jnp reference path (used on CPU and as the oracle)
  - "flash": Pallas flash-attention kernel (TPU target; interpret-mode on CPU)

Shapes follow [batch, seq, heads, head_dim] ("BSHD").
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Params,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, max_seq: int, theta: float = 10000.0,
                     dtype=jnp.float32) -> jnp.ndarray:
    """[max_seq, head_dim//2] angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    return jnp.outer(t, inv).astype(dtype)  # [S, D/2]


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, D]; angles: [S, D/2] (already positioned)."""
    d_half = x.shape[-1] // 2
    x1, x2 = x[..., :d_half], x[..., d_half:]
    cos = jnp.cos(angles)[None, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Core scaled-dot-product attention (reference / XLA path)
# ---------------------------------------------------------------------------

def sdpa_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
             causal: bool = False, bias: jnp.ndarray | None = None,
             q_offset: int = 0, scale: float | None = None) -> jnp.ndarray:
    """q/k: [B,Sq,Hq,D], v: [B,Sk,Hkv,Dv] with Hq % Hkv == 0 (GQA).

    Dv may differ from D (MLA: qk_head_dim != v_head_dim).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]  # [Sq, Sk]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


CHUNKED_THRESHOLD = 2048  # switch to q-chunked attention above this seq len
# §Perf knob: q-chunk size. Bigger chunks re-read K/V fewer times (bytes
# scale ~ S/chunk) at the cost of a larger transient logits tile.
import os as _os
_CHUNK = int(_os.environ.get("REPRO_ATTN_CHUNK", "1024"))


def sdpa_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool = False, q_offset: int = 0,
                 scale: float | None = None,
                 chunk: int = 1024) -> jnp.ndarray:
    """Exact attention with O(chunk * Sk) logits memory via lax.scan over
    query chunks — the XLA-lowerable stand-in for the Pallas flash kernel
    (same math, bounded VMEM/HBO footprint; on a real TPU the flash
    kernel replaces this path). Sq must be divisible by `chunk` (callers
    route through here only for long, power-of-two sequence cells)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    if Sq % chunk != 0:
        return sdpa_xla(q, k, v, causal=causal, q_offset=q_offset,
                        scale=scale)
    n_chunks = Sq // chunk
    qc = q.reshape(B, n_chunks, chunk, Hq, D).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(Sk)

    def body(carry, xs):
        qi, i = xs
        qg = qi.reshape(B, chunk, Hkv, group, D)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if causal:
            qpos = i * chunk + jnp.arange(chunk) + q_offset
            mask = kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
        return carry, o.reshape(B, chunk, Hq, Dv).astype(q.dtype)

    from repro.models.layers import scan_unroll
    _, out = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)),
                          unroll=scan_unroll())
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, Dv)


def sdpa(q, k, v, *, causal=False, bias=None, q_offset=0, impl="xla",
         scale=None):
    if impl == "flash" and bias is None:
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, causal=causal,
                                         q_offset=q_offset, scale=scale)
    if bias is None and q.shape[1] >= CHUNKED_THRESHOLD:
        return sdpa_chunked(q, k, v, causal=causal, q_offset=q_offset,
                            scale=scale, chunk=_CHUNK)
    return sdpa_xla(q, k, v, causal=causal, bias=bias, q_offset=q_offset,
                    scale=scale)


# ---------------------------------------------------------------------------
# GQA attention block (dense LMs, ViT with Hkv == Hq)
# ---------------------------------------------------------------------------

def gqa_init(key, d_model: int, n_heads: int, n_kv_heads: int,
             head_dim: int | None = None, *, bias: bool = False,
             dtype=jnp.float32) -> Params:
    head_dim = head_dim or d_model // n_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": linear_init(kq, d_model, n_heads * head_dim, bias=bias,
                          dtype=dtype),
        "wk": linear_init(kk, d_model, n_kv_heads * head_dim,
                          bias=bias, dtype=dtype),
        "wv": linear_init(kv, d_model, n_kv_heads * head_dim,
                          bias=bias, dtype=dtype),
        "wo": linear_init(ko, n_heads * head_dim, d_model, bias=bias,
                          dtype=dtype),
    }


def gqa_qkv(p: Params, x: jnp.ndarray, n_heads: int, n_kv_heads: int):
    B, S, _ = x.shape
    q = linear(p["wq"], x).reshape(B, S, n_heads, -1)
    k = linear(p["wk"], x).reshape(B, S, n_kv_heads, -1)
    v = linear(p["wv"], x).reshape(B, S, n_kv_heads, -1)
    return q, k, v


def gqa_attention(p: Params, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
                  angles: jnp.ndarray | None = None, causal: bool = True,
                  impl: str = "xla") -> jnp.ndarray:
    from repro.models.layers import constrain_act
    B, S, _ = x.shape
    q, k, v = gqa_qkv(p, x, n_heads, n_kv_heads)
    if angles is not None:
        q = apply_rope(q, angles[:S])
        k = apply_rope(k, angles[:S])
    # §Perf: keep batch on DP + heads on TP through the attention matmuls
    q = constrain_act(q, (None, "model", None))
    k = constrain_act(k, (None, "model" if n_kv_heads == n_heads else None,
                          None))
    v = constrain_act(v, (None, "model" if n_kv_heads == n_heads else None,
                          None))
    o = sdpa(q, k, v, causal=causal, impl=impl)
    o = constrain_act(o, (None, "model", None))
    return linear(p["wo"], o.reshape(B, S, -1))


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2/V3 style)
# ---------------------------------------------------------------------------
# Queries/keys/values are projected through low-rank latents; the KV cache
# stores only the compressed latent (kv_lora_rank) + a small rope'd key part.

def mla_init(key, d_model: int, n_heads: int, *, q_lora_rank: int,
             kv_lora_rank: int, qk_nope_dim: int, qk_rope_dim: int,
             v_head_dim: int, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    qk_head_dim = qk_nope_dim + qk_rope_dim
    return {
        "wq_a": linear_init(keys[0], d_model, q_lora_rank, bias=False,
                            dtype=dtype),
        "q_a_norm": rmsnorm_init(q_lora_rank, dtype=dtype),
        "wq_b": linear_init(keys[1], q_lora_rank, n_heads * qk_head_dim,
                            bias=False, dtype=dtype),
        "wkv_a": linear_init(keys[2], d_model, kv_lora_rank + qk_rope_dim,
                             bias=False, dtype=dtype),
        "kv_a_norm": rmsnorm_init(kv_lora_rank, dtype=dtype),
        "wkv_b": linear_init(keys[3], kv_lora_rank,
                             n_heads * (qk_nope_dim + v_head_dim),
                             bias=False, dtype=dtype),
        "wo": linear_init(keys[4], n_heads * v_head_dim, d_model, bias=False,
                          dtype=dtype),
    }


def mla_attention(p: Params, x: jnp.ndarray, *, n_heads: int, qk_nope_dim: int,
                  qk_rope_dim: int, v_head_dim: int, kv_lora_rank: int,
                  angles: jnp.ndarray | None = None, causal: bool = True,
                  impl: str = "xla") -> jnp.ndarray:
    """Training/prefill-path MLA (latents expanded; cache-path in
    kvcache.py)."""
    B, S, _ = x.shape
    qk_head_dim = qk_nope_dim + qk_rope_dim

    q_lat = rmsnorm(p["q_a_norm"], linear(p["wq_a"], x))
    q = linear(p["wq_b"], q_lat).reshape(B, S, n_heads, qk_head_dim)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]

    kv_a = linear(p["wkv_a"], x)
    kv_lat = rmsnorm(p["kv_a_norm"], kv_a[..., :kv_lora_rank])
    k_rope = kv_a[..., kv_lora_rank:].reshape(B, S, 1, qk_rope_dim)

    kv = linear(p["wkv_b"], kv_lat).reshape(B, S, n_heads,
                                            qk_nope_dim + v_head_dim)
    k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]

    if angles is not None:
        q_rope = apply_rope(q_rope, angles[:S, : qk_rope_dim // 2])
        k_rope = apply_rope(k_rope, angles[:S, : qk_rope_dim // 2])

    k_rope = jnp.broadcast_to(k_rope, (B, S, n_heads, qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    from repro.models.layers import constrain_act
    q_full = constrain_act(q_full, (None, "model", None))
    k_full = constrain_act(k_full, (None, "model", None))
    v = constrain_act(v, (None, "model", None))

    # v_head_dim may differ from qk_head_dim; pad v for the fused kernel path.
    scale = 1.0 / math.sqrt(qk_head_dim)
    if impl == "flash" and v_head_dim != qk_head_dim:
        pad = qk_head_dim - v_head_dim
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, max(0, pad))))
        o = sdpa(q_full, k_full, v_p, causal=causal, impl=impl, scale=scale)
        o = o[..., :v_head_dim]
    else:
        o = sdpa(q_full, k_full, v, causal=causal, impl="xla", scale=scale)
    return linear(p["wo"], o.reshape(B, S, n_heads * v_head_dim))


# ---------------------------------------------------------------------------
# Windowed attention (Swin)
# ---------------------------------------------------------------------------

def window_partition(x: jnp.ndarray, window: int) -> jnp.ndarray:
    """[B,H,W,C] -> [B*nW, window*window, C]."""
    B, H, W, C = x.shape
    x = x.reshape(B, H // window, window, W // window, window, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(-1, window * window, C)


def window_unpartition(wins: jnp.ndarray, window: int, H: int,
                       W: int) -> jnp.ndarray:
    B = wins.shape[0] // ((H // window) * (W // window))
    x = wins.reshape(B, H // window, W // window, window, window, -1)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, H, W, -1)


def shifted_window_mask(H: int, W: int, window: int,
                        shift: int) -> jnp.ndarray:
    """Attention bias [nW, window^2, window^2] for shifted windows (Swin)."""
    img = jnp.zeros((1, H, W, 1))
    cnt = 0
    h_slices = ((0, H - window), (H - window, H - shift), (H - shift, H))
    w_slices = ((0, W - window), (W - window, W - shift), (W - shift, W))
    for hs, he in h_slices:
        for ws, we in w_slices:
            img = img.at[:, hs:he, ws:we, :].set(cnt)
            cnt += 1
    wins = window_partition(img, window).squeeze(-1)  # [nW, window^2]
    diff = wins[:, :, None] - wins[:, None, :]
    return jnp.where(diff == 0, 0.0, -1e9)  # [nW, w^2, w^2]


def window_attention(p: Params, x: jnp.ndarray, *, n_heads: int,
                     rel_bias: jnp.ndarray | None = None,
                     mask: jnp.ndarray | None = None,
                     impl: str = "xla") -> jnp.ndarray:
    """x: [nWB, T, C] windows; rel_bias: [n_heads, T, T]; mask: [nW, T, T]."""
    nWB, T, C = x.shape
    q = linear(p["wq"], x).reshape(nWB, T, n_heads, -1)
    k = linear(p["wk"], x).reshape(nWB, T, n_heads, -1)
    v = linear(p["wv"], x).reshape(nWB, T, n_heads, -1)
    bias = None
    if rel_bias is not None:
        bias = rel_bias[None, :, None]  # [1, H, 1, T, T] (g axis broadcast)
    if mask is not None:
        nW = mask.shape[0]
        m = jnp.tile(mask, (nWB // nW, 1, 1))[:, None, None]  # [nWB,1,1,T,T]
        bias = m if bias is None else bias + m
    o = sdpa_xla(q, k, v, causal=False, bias=bias)
    return linear(p["wo"], o.reshape(nWB, T, C))
