"""Dense decoder-only LM (StableLM family) — pure JAX, scan-over-layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    embedding,
    embedding_init,
    linear,
    linear_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    scan_layers,
    stack_init,
)


def dense_block_init(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "attn": attn.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.resolved_head_dim, dtype=cfg.dtype),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, gated=True, bias=False,
                        dtype=cfg.dtype),
    }


def dense_block(p: Params, x: jnp.ndarray, cfg: LMConfig,
                angles: jnp.ndarray, impl: str) -> jnp.ndarray:
    h = attn.gqa_attention(p["attn"], rmsnorm(p["attn_norm"], x),
                           n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                           angles=angles, causal=True, impl=impl)
    x = x + h
    x = x + mlp(p["mlp"], rmsnorm(p["mlp_norm"], x))
    return x


def lm_init(key, cfg: LMConfig) -> Params:
    ke, kl, ko = jax.random.split(key, 3)
    params = {
        "embed": embedding_init(ke, cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "layers": stack_init(kl, cfg.n_layers,
                             lambda k: dense_block_init(k, cfg)),
        "final_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(ko, cfg.d_model, cfg.vocab, bias=False,
                                        dtype=cfg.dtype)
    return params


def lm_forward(params: Params, cfg: LMConfig, tokens: jnp.ndarray, *,
               impl: str = "xla") -> jnp.ndarray:
    """tokens [B, S] -> logits [B, S, V]."""
    S = tokens.shape[1]
    x = embedding(params["embed"], tokens)
    angles = attn.rope_frequencies(cfg.resolved_head_dim, S, cfg.rope_theta)

    def body(layer_p, carry, extra):
        return dense_block(layer_p, carry, cfg, extra, "xla")

    x = scan_layers(body, params["layers"], x, extra=angles,
                    remat=cfg.remat, remat_policy="dots_no_batch")
    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = linear(params["lm_head"], x)
    return logits


def lm_loss(params: Params, cfg: LMConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray) -> jnp.ndarray:
    logits = lm_forward(params, cfg, tokens).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).squeeze(-1)
    return jnp.mean(nll)
