"""KV caches + prefill/decode serve steps for dense and MoE LMs.

Two cache layouts:
  - GQA cache: k/v [L, B, max_seq, Hkv, Dh]       (stablelm, kimi)
  - MLA cache: kv_latent [L, B, max_seq, lora] + k_rope [L, B, max_seq, rope]
    (deepseek) — the paper-exact compressed cache; decode uses the
    weight-absorption trick so per-step FLOPs stay O(S·H·(lora+rope)).

For `long_500k` the sequence axis of the cache is sharded over the `model`
mesh axis (sequence parallelism); the attention contraction then produces
sharded partial logits which GSPMD combines — a flash-decode-style split-S
softmax (we lower the exact masked softmax; XLA's partitioner handles the
cross-shard reduction).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    scan_unroll,
    embedding,
    linear,
    mlp,
    rmsnorm,
)


class GQACache(NamedTuple):
    k: jnp.ndarray       # [L, B, S_max, Hkv, Dh]
    v: jnp.ndarray       # [L, B, S_max, Hkv, Dh]
    length: jnp.ndarray  # [] int32 — tokens currently valid


class MLACache(NamedTuple):
    kv_latent: jnp.ndarray  # [L, B, S_max, lora]
    k_rope: jnp.ndarray     # [L, B, S_max, rope]
    length: jnp.ndarray


def init_gqa_cache(cfg: LMConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> GQACache:
    Dh = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, Dh)
    return GQACache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                    jnp.zeros((), jnp.int32))


def init_mla_cache(cfg: LMConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        jnp.zeros((cfg.n_layers, batch, max_seq, cfg.kv_lora_rank), dtype),
        jnp.zeros((cfg.n_layers, batch, max_seq, cfg.qk_rope_dim), dtype),
        jnp.zeros((), jnp.int32))


def cache_specs(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    if cfg.mla:
        return MLACache(
            jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_seq, cfg.kv_lora_rank), dtype),
            jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_seq, cfg.qk_rope_dim), dtype),
            jax.ShapeDtypeStruct((), jnp.int32))
    Dh = cfg.resolved_head_dim
    s = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, Dh), dtype)
    return GQACache(s, s, jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# Masked decode attention over a cache slice
# ---------------------------------------------------------------------------

def _decode_attend(q, k_cache, v_cache, length, scale):
    """q [B,1,Hq,D]; k/v [B,S,Hkv,D]; attend to positions < length + 1."""
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = (jnp.arange(S) <= length)[None, None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA decode step (dense blocks; used by stablelm + kimi attention part)
# ---------------------------------------------------------------------------

def _gqa_block_decode(lp: Params, x, k_cache, v_cache, length, cfg: LMConfig,
                      angles_pos):
    """One dense block at decode; returns (x, new_k_slice, new_v_slice)."""
    B = x.shape[0]
    Dh = cfg.resolved_head_dim
    h = rmsnorm(lp["attn_norm"], x)
    q = linear(lp["attn"]["wq"], h).reshape(B, 1, cfg.n_heads, Dh)
    k = linear(lp["attn"]["wk"], h).reshape(B, 1, cfg.n_kv_heads, Dh)
    v = linear(lp["attn"]["wv"], h).reshape(B, 1, cfg.n_kv_heads, Dh)
    q = attn.apply_rope(q, angles_pos)
    k = attn.apply_rope(k, angles_pos)

    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, length, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, length, 0, 0))

    o = _decode_attend(q, k_cache, v_cache, length, 1.0 / math.sqrt(Dh))
    x = x + linear(lp["attn"]["wo"], o.reshape(B, 1, -1))
    x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))
    return x, k_cache, v_cache


def gqa_decode_step(params: Params, cfg: LMConfig, token: jnp.ndarray,
                    cache: GQACache):
    """token [B,1] -> (logits [B,1,V], cache'). Dense LM only."""
    B = token.shape[0]
    x = embedding(params["embed"], token)
    length = cache.length
    pos_angles = attn.rope_frequencies(
        cfg.resolved_head_dim, cache.k.shape[2], cfg.rope_theta)
    angles_pos = jax.lax.dynamic_slice_in_dim(pos_angles, length, 1, axis=0)

    def body(carry, layer_io):
        x = carry
        lp, kc, vc = layer_io
        x, kc, vc = _gqa_block_decode(lp, x, kc, vc, length, cfg, angles_pos)
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache.k, cache.v),
        unroll=scan_unroll())
    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = linear(params["lm_head"], x)
    return logits, GQACache(new_k, new_v, length + 1)


# ---------------------------------------------------------------------------
# MLA decode step with weight absorption (deepseek-family)
# ---------------------------------------------------------------------------

def _mla_block_decode(lp: Params, x, kv_lat_cache, k_rope_cache, length,
                      cfg: LMConfig, angles_pos):
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                            cfg.v_head_dim, cfg.kv_lora_rank)
    ap = lp["attn"]
    h = rmsnorm(ap["attn_norm"], x) if "attn_norm" in ap else rmsnorm(
        lp["attn_norm"], x)

    q_lat = rmsnorm(ap["q_a_norm"], linear(ap["wq_a"], h))
    q = linear(ap["wq_b"], q_lat).reshape(B, 1, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = attn.apply_rope(q_rope, angles_pos[:, : rope // 2])

    kv_a = linear(ap["wkv_a"], h)                        # [B,1,lora+rope]
    kv_lat = rmsnorm(ap["kv_a_norm"], kv_a[..., :lora])  # [B,1,lora]
    k_rope_new = attn.apply_rope(
        kv_a[..., lora:].reshape(B, 1, 1, rope), angles_pos[:, : rope // 2]
    ).reshape(B, 1, rope)

    kv_lat_cache = jax.lax.dynamic_update_slice(
        kv_lat_cache, kv_lat.astype(kv_lat_cache.dtype), (0, length, 0))
    k_rope_cache = jax.lax.dynamic_update_slice(
        k_rope_cache, k_rope_new.astype(k_rope_cache.dtype), (0, length, 0))

    # Weight absorption: w_kv_b [lora, H*(nope+vd)] split into K and V parts.
    wkvb = ap["wkv_b"]["w"].reshape(lora, H, nope + vd)
    w_k = wkvb[..., :nope]                              # [lora, H, nope]
    w_v = wkvb[..., nope:]                              # [lora, H, vd]

    # Project q_nope into latent space: q_lat' = q_nope @ w_k^T  [B,1,H,lora]
    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))

    scale = 1.0 / math.sqrt(nope + rope)
    S = kv_lat_cache.shape[1]
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_abs,
                         kv_lat_cache.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           k_rope_cache.astype(jnp.float32))) * scale
    valid = (jnp.arange(S) <= length)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", w,
                       kv_lat_cache.astype(jnp.float32))   # [B,1,H,lora]
    o = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_v.astype(jnp.float32))

    x = x + linear(ap["wo"], o.reshape(B, 1, H * vd).astype(x.dtype))
    return x, kv_lat_cache, k_rope_cache


def _moe_or_mlp(lp: Params, x, cfg: LMConfig):
    from repro.models import moe as moe_mod
    h = rmsnorm(lp["mlp_norm"], x)
    if "moe" in lp:
        y, _ = moe_mod.moe_ffn(lp["moe"], h, cfg)
        return x + y
    return x + mlp(lp["mlp"], h)


def mla_decode_step(params: Params, cfg: LMConfig, token: jnp.ndarray,
                    cache: MLACache):
    """MoE-MLA decode (deepseek). token [B,1] -> (logits, cache')."""
    B = token.shape[0]
    x = embedding(params["embed"], token)
    length = cache.length
    S_max = cache.kv_latent.shape[2]
    pos_angles = attn.rope_frequencies(cfg.qk_rope_dim, S_max, cfg.rope_theta)
    angles_pos = jax.lax.dynamic_slice_in_dim(pos_angles, length, 1, axis=0)

    n_dense = cfg.first_dense_layers
    for i, lp in enumerate(params["dense_layers"]):
        wrapped = {"attn": lp["attn"], "attn_norm": lp["attn_norm"]}
        wrapped["attn"] = dict(lp["attn"])
        wrapped["attn"]["attn_norm"] = lp["attn_norm"]
        x, kv_l, k_r = _mla_block_decode(
            {"attn": wrapped["attn"], "attn_norm": lp["attn_norm"],
             "mlp_norm": lp["mlp_norm"], "mlp": lp["mlp"]},
            x, cache.kv_latent[i], cache.k_rope[i], length, cfg, angles_pos)
        cache = cache._replace(
            kv_latent=cache.kv_latent.at[i].set(kv_l),
            k_rope=cache.k_rope.at[i].set(k_r))
        x = _moe_or_mlp({"mlp_norm": lp["mlp_norm"], "mlp": lp["mlp"]}, x, cfg)

    moe_kv = cache.kv_latent[n_dense:]
    moe_kr = cache.k_rope[n_dense:]

    def body(carry, layer_io):
        x = carry
        lp, kvl, krp = layer_io
        x, kvl, krp = _mla_block_decode(
            {"attn": lp["attn"], "attn_norm": lp["attn_norm"]}, x, kvl, krp,
            length, cfg, angles_pos)
        x = _moe_or_mlp(lp, x, cfg)
        return x, (kvl, krp)

    x, (new_kvl, new_krp) = jax.lax.scan(
        body, x, (params["moe_layers"], moe_kv, moe_kr),
        unroll=scan_unroll())
    kv_latent = jnp.concatenate([cache.kv_latent[:n_dense], new_kvl], axis=0)
    k_rope = jnp.concatenate([cache.k_rope[:n_dense], new_krp], axis=0)

    x = rmsnorm(params["final_norm"], x)
    logits = linear(params["lm_head"], x)
    return logits, MLACache(kv_latent, k_rope, length + 1)


# ---------------------------------------------------------------------------
# Prefill (full-sequence forward that also fills the cache)
# ---------------------------------------------------------------------------

def gqa_prefill(params: Params, cfg: LMConfig, tokens: jnp.ndarray,
                max_seq: int | None = None, *, last_only: bool = False):
    """tokens [B,S] -> (logits [B,S,V], GQACache filled to S).

    last_only=True computes logits for the final position only — serving
    prefill needs just the first sampled token, and a [B,S,V] logits
    tensor at 32k x 129k vocab is ~270 GB of pointless HBM traffic."""
    B, S = tokens.shape
    max_seq = max_seq or S
    Dh = cfg.resolved_head_dim
    angles = attn.rope_frequencies(Dh, S, cfg.rope_theta)
    x = embedding(params["embed"], tokens)

    def body(carry, lp):
        x = carry
        h = rmsnorm(lp["attn_norm"], x)
        q, k, v = attn.gqa_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads)
        q = attn.apply_rope(q, angles)
        k = attn.apply_rope(k, angles)
        o = attn.sdpa(q, k, v, causal=True, impl="xla")
        x = x + linear(lp["attn"]["wo"], o.reshape(B, S, -1))
        x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))
        return x, (k, v)

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    ) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(lambda c, lp: body_fn(c, lp), x,
                               params["layers"], unroll=scan_unroll())

    x = rmsnorm(params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = linear(params["lm_head"], x)

    pad = max_seq - S
    k_cache = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
        jnp.bfloat16)
    v_cache = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
        jnp.bfloat16)
    return logits, GQACache(k_cache, v_cache, jnp.asarray(S, jnp.int32))


def mla_prefill(params: Params, cfg: LMConfig, tokens: jnp.ndarray,
                max_seq: int | None = None, *, last_only: bool = False):
    """MoE-MLA prefill (deepseek). tokens [B,S] -> (logits, MLACache).

    The cache stores only the compressed latent + rope'd key — per-token
    cache bytes are (lora + rope) vs GQA's 2*Hkv*Dh, a 10-40x shrink.
    """
    B, S = tokens.shape
    max_seq = max_seq or S
    lora, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
    nope, vd, H = cfg.qk_nope_dim, cfg.v_head_dim, cfg.n_heads
    angles = attn.rope_frequencies(rope, S, cfg.rope_theta)
    x = embedding(params["embed"], tokens)

    def block(lp, x):
        """Full MLA attention; returns (x, kv_lat [B,S,lora],
        k_rope [B,S,rope])."""
        ap = lp["attn"]
        h = rmsnorm(lp["attn_norm"], x)
        q_lat = rmsnorm(ap["q_a_norm"], linear(ap["wq_a"], h))
        q = linear(ap["wq_b"], q_lat).reshape(B, S, H, nope + rope)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        q_rope = attn.apply_rope(q_rope, angles[:, : rope // 2])

        kv_a = linear(ap["wkv_a"], h)
        kv_lat = rmsnorm(ap["kv_a_norm"], kv_a[..., :lora])
        k_rope = attn.apply_rope(
            kv_a[..., lora:].reshape(B, S, 1, rope), angles[:, : rope // 2])

        kv = linear(ap["wkv_b"], kv_lat).reshape(B, S, H, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attn.sdpa(q_full, k_full, v, causal=True, impl="xla",
                      scale=1.0 / math.sqrt(nope + rope))
        x = x + linear(ap["wo"], o.reshape(B, S, H * vd))
        return x, kv_lat, k_rope.reshape(B, S, rope)

    lat_list, rope_list = [], []
    for lp in params["dense_layers"]:
        x, kvl, krp = block(lp, x)
        x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))
        lat_list.append(kvl)
        rope_list.append(krp)

    from repro.models import moe as moe_mod

    def body(carry, lp):
        x = carry
        x, kvl, krp = block(lp, x)
        y, _ = moe_mod.moe_ffn(lp["moe"], rmsnorm(lp["mlp_norm"], x), cfg)
        return x + y, (kvl, krp)

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    ) if cfg.remat else body
    x, (moe_lat, moe_rope) = jax.lax.scan(body_fn, x, params["moe_layers"],
                                          unroll=scan_unroll())

    x = rmsnorm(params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    logits = linear(params["lm_head"], x)

    n_dense = cfg.first_dense_layers
    if n_dense:
        kv_latent = jnp.concatenate(
            [jnp.stack(lat_list, axis=0), moe_lat], axis=0)
        k_rope_all = jnp.concatenate(
            [jnp.stack(rope_list, axis=0), moe_rope], axis=0)
    else:
        kv_latent, k_rope_all = moe_lat, moe_rope

    pad = max_seq - S
    kv_latent = jnp.pad(
        kv_latent, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16)
    k_rope_all = jnp.pad(
        k_rope_all, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16)
    return logits, MLACache(kv_latent, k_rope_all, jnp.asarray(S, jnp.int32))


def moe_gqa_prefill(params: Params, cfg: LMConfig, tokens: jnp.ndarray,
                    max_seq: int | None = None, *, last_only: bool = False):
    """MoE-GQA prefill (kimi). tokens [B,S] -> (logits, GQACache)."""
    B, S = tokens.shape
    max_seq = max_seq or S
    Dh = cfg.resolved_head_dim
    angles = attn.rope_frequencies(Dh, S, cfg.rope_theta)
    x = embedding(params["embed"], tokens)

    def attend(lp, x):
        h = rmsnorm(lp["attn_norm"], x)
        q, k, v = attn.gqa_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads)
        q = attn.apply_rope(q, angles)
        k = attn.apply_rope(k, angles)
        o = attn.sdpa(q, k, v, causal=True, impl="xla")
        return x + linear(lp["attn"]["wo"], o.reshape(B, S, -1)), k, v

    k_list, v_list = [], []
    for lp in params["dense_layers"]:
        x, k, v = attend(lp, x)
        x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))
        k_list.append(k)
        v_list.append(v)

    from repro.models import moe as moe_mod

    def body(carry, lp):
        x = carry
        x, k, v = attend(lp, x)
        y, _ = moe_mod.moe_ffn(lp["moe"], rmsnorm(lp["mlp_norm"], x), cfg)
        return x + y, (k, v)

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    ) if cfg.remat else body
    x, (moe_k, moe_v) = jax.lax.scan(body_fn, x, params["moe_layers"],
                                     unroll=scan_unroll())

    x = rmsnorm(params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    logits = linear(params["lm_head"], x)

    if cfg.first_dense_layers:
        ks = jnp.concatenate([jnp.stack(k_list, axis=0), moe_k], axis=0)
        vs = jnp.concatenate([jnp.stack(v_list, axis=0), moe_v], axis=0)
    else:
        ks, vs = moe_k, moe_v
    pad = max_seq - S
    k_cache = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
        jnp.bfloat16)
    v_cache = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
        jnp.bfloat16)
    return logits, GQACache(k_cache, v_cache, jnp.asarray(S, jnp.int32))


def moe_gqa_decode_step(params: Params, cfg: LMConfig, token: jnp.ndarray,
                        cache: GQACache):
    """MoE-GQA decode (kimi). token [B,1] -> (logits, cache')."""
    B = token.shape[0]
    x = embedding(params["embed"], token)
    length = cache.length
    S_max = cache.k.shape[2]
    Dh = cfg.resolved_head_dim
    pos_angles = attn.rope_frequencies(Dh, S_max, cfg.rope_theta)
    angles_pos = jax.lax.dynamic_slice_in_dim(pos_angles, length, 1, axis=0)
    n_dense = cfg.first_dense_layers

    def attend_decode(lp, x, kc, vc):
        h = rmsnorm(lp["attn_norm"], x)
        q = linear(lp["attn"]["wq"], h).reshape(B, 1, cfg.n_heads, Dh)
        k = linear(lp["attn"]["wk"], h).reshape(B, 1, cfg.n_kv_heads, Dh)
        v = linear(lp["attn"]["wv"], h).reshape(B, 1, cfg.n_kv_heads, Dh)
        q = attn.apply_rope(q, angles_pos)
        k = attn.apply_rope(k, angles_pos)
        kc = jax.lax.dynamic_update_slice(
            kc, k.astype(kc.dtype), (0, length, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v.astype(vc.dtype), (0, length, 0, 0))
        o = _decode_attend(q, kc, vc, length, 1.0 / math.sqrt(Dh))
        return x + linear(lp["attn"]["wo"], o.reshape(B, 1, -1)), kc, vc

    k_cache, v_cache = cache.k, cache.v
    for i, lp in enumerate(params["dense_layers"]):
        x, kc, vc = attend_decode(lp, x, k_cache[i], v_cache[i])
        k_cache = k_cache.at[i].set(kc)
        v_cache = v_cache.at[i].set(vc)
        x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))

    def body(carry, layer_io):
        x = carry
        lp, kc, vc = layer_io
        x, kc, vc = attend_decode(lp, x, kc, vc)
        x = _moe_or_mlp(lp, x, cfg)
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["moe_layers"], k_cache[n_dense:], v_cache[n_dense:]),
        unroll=scan_unroll())
    if n_dense:
        k_cache = jnp.concatenate([k_cache[:n_dense], new_k], axis=0)
        v_cache = jnp.concatenate([v_cache[:n_dense], new_v], axis=0)
    else:
        k_cache, v_cache = new_k, new_v

    x = rmsnorm(params["final_norm"], x)
    logits = linear(params["lm_head"], x)
    return logits, GQACache(k_cache, v_cache, length + 1)
