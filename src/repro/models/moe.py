"""Mixture-of-Experts layer: top-k router + capacity-based static dispatch.

The dispatch is the GShard/MaxText-style static-shaped formulation adapted for
expert parallelism on TPU meshes:

  1. router logits [T, E] -> top-k gates (softmax over chosen experts)
  2. position-in-expert via cumulative-sum of one-hot assignments, with a
     fixed per-expert capacity C (overflow tokens are dropped — capacity
     factor defaults to 1.25 like GShard)
  3. scatter tokens into a dense [E, C, D] buffer (expert axis shardable over
     the 'model'/'expert' mesh axis -> GSPMD inserts the all-to-all)
  4. grouped expert FFN via einsum over the leading E axis (MXU-friendly)
  5. gather back and combine with gates

A shared-expert branch (DeepSeek/Kimi style) runs densely over all tokens.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.layers import Params, linear_init, trunc_normal


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray        # load-balancing loss (Switch-style)
    dropped_frac: jnp.ndarray    # fraction of (token, k) assignments dropped


def moe_init(key, cfg: LMConfig) -> Params:
    E = cfg.moe_experts
    dff = cfg.moe_d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": {"w": trunc_normal(kr, (cfg.d_model, E), std=0.02,
                                     dtype=jnp.float32)},
        # stacked expert weights: [E, d_model, dff] / [E, dff, d_model]
        "w_gate": trunc_normal(kg, (E, cfg.d_model, dff), std=0.02,
                               dtype=cfg.dtype),
        "w_up": trunc_normal(ku, (E, cfg.d_model, dff), std=0.02,
                             dtype=cfg.dtype),
        "w_down": trunc_normal(kd, (E, dff, cfg.d_model), std=0.02,
                               dtype=cfg.dtype),
    }
    if cfg.moe_shared_experts > 0:
        sdff = dff * cfg.moe_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "gate": linear_init(k1, cfg.d_model, sdff, bias=False,
                                dtype=cfg.dtype),
            "up": linear_init(k2, cfg.d_model, sdff, bias=False,
                              dtype=cfg.dtype),
            "down": linear_init(k3, sdff, cfg.d_model, bias=False,
                                dtype=cfg.dtype),
        }
    return p


def router_topk(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x [T, D] -> (gates [T, k], ids [T, k], probs [T, E])."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids, probs


def _positions_in_runs(sorted_keys: jnp.ndarray) -> jnp.ndarray:
    """For a sorted int array, the rank of each element within its run of
    equal values. O(n) memory — replaces the O(T*K x E) one-hot cumsum
    that is catastrophic at megatoken scale."""
    n = sorted_keys.shape[0]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    return idx - seg_start


import os

_CF_ENV = os.environ.get("REPRO_MOE_CF", "")


def moe_ffn(p: Params, x: jnp.ndarray, cfg: LMConfig, *,
            capacity_factor: float = 1.25):
    """x [B, S, D] -> (y [B, S, D], MoEMetrics).

    Sort-based token dispatch (MaxText-style): assignments are sorted by
    expert id, positions-within-expert come from run ranks, and tokens
    scatter into a dense [E, C, D] buffer whose expert axis shards over
    the `model` mesh axis (GSPMD inserts the all-to-all). All
    intermediates are O(T*K) or O(E*C*D) — no [T, E] materialization.
    """
    if _CF_ENV:  # §Perf knob: REPRO_MOE_CF overrides the capacity factor
        capacity_factor = float(_CF_ENV)
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, D)

    gates, ids, probs = router_topk(p["router"]["w"], xt, K)

    # Capacity per expert (static): ceil(T * K / E * cf), multiple of 8.
    C = int(max(8, -(-int(T * K * capacity_factor) // E)))
    C = min(C + (-C) % 8, max(T, 8))

    e_flat = ids.reshape(T * K)
    g_flat = gates.reshape(T * K).astype(x.dtype)

    order = jnp.argsort(e_flat)                        # stable
    sorted_e = e_flat[order]
    pos_in_e = _positions_in_runs(sorted_e)
    keep = pos_in_e < C
    dropped_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))

    tok = order // K                                   # token of each slot
    safe_e = jnp.where(keep, sorted_e, 0)
    safe_pos = jnp.where(keep, pos_in_e, 0)
    # NOTE (§Perf, refuted hypothesis): forcing dp sharding on the
    # permutation-ordered dispatch arrays here inserts all-to-all reshards
    # that cost 5x more than GSPMD's own strategy — measured and reverted
    # (EXPERIMENTS.md §Perf, deepseek train_4k iteration 2).
    vals = xt[tok] * keep[:, None].astype(x.dtype)     # [T*K, D]
    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    buf = buf.at[safe_e, safe_pos].add(vals)           # dropped rows add 0s

    # Grouped expert FFN (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    # Gather back, gate-combine, unsort
    y_sorted = y_buf[safe_e, safe_pos] * keep[:, None].astype(x.dtype)
    y_sorted = y_sorted * g_flat[order][:, None]
    y_flat = jnp.zeros((T * K, D), x.dtype).at[order].set(y_sorted)
    y = jnp.sum(y_flat.reshape(T, K, D), axis=1)

    # Shared-expert branch
    if "shared" in p:
        sh = p["shared"]
        hg = jax.nn.silu(xt @ sh["gate"]["w"].astype(x.dtype))
        hu = xt @ sh["up"]["w"].astype(x.dtype)
        y = y + (hg * hu) @ sh["down"]["w"].astype(x.dtype)

    # Switch-style load-balancing aux loss
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    return y.reshape(B, S, D), MoEMetrics(aux, dropped_frac)
