"""DiT-L/2 (adaLN-zero) — Peebles & Xie, arXiv:2212.09748. Pure JAX.

Operates on latents [B, latent_res, latent_res, C] (latent_res = img_res/8
for a stub VAE; the pool treats the backbone as the deliverable).
Conditioning = timestep + class label embeddings (adaLN-zero modulation).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import DiffusionConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    conv2d,
    conv_init,
    linear,
    linear_init,
    mlp,
    mlp_init,
    modulated_layernorm,
    scan_layers,
    stack_init,
    trunc_normal,
)


def timestep_embedding(t: jnp.ndarray, dim: int,
                       max_period: float = 10000.0) -> jnp.ndarray:
    """t [B] (float timesteps) -> [B, dim] sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def dit_block_init(key, cfg: DiffusionConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.d_model
    return {
        "attn": attn.gqa_init(k1, D, cfg.n_heads, cfg.n_heads, bias=True,
                              dtype=cfg.dtype),
        "mlp": mlp_init(k2, D, 4 * D, dtype=cfg.dtype),
        # adaLN-zero: 6 modulation vectors; final layer zero-init
        "ada": {"w": jnp.zeros((D, 6 * D), dtype=cfg.dtype),
                "b": jnp.zeros((6 * D,), dtype=cfg.dtype)},
    }


def dit_block(p: Params, x: jnp.ndarray, c: jnp.ndarray,
              cfg: DiffusionConfig) -> jnp.ndarray:
    """x [B,T,D]; c [B,D] conditioning."""
    mod = linear(p["ada"], jax.nn.silu(c))            # [B, 6D]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod[:, None, :], 6, axis=-1)
    h = modulated_layernorm({}, x, sh1, sc1)
    h = attn.gqa_attention(p["attn"], h, n_heads=cfg.n_heads,
                           n_kv_heads=cfg.n_heads, angles=None, causal=False)
    x = x + g1 * h
    h = modulated_layernorm({}, x, sh2, sc2)
    x = x + g2 * mlp(p["mlp"], h)
    return x


def dit_init(key, cfg: DiffusionConfig) -> Params:
    latent_res = cfg.latent_res or cfg.img_res // 8
    n_tokens = (latent_res // cfg.patch) ** 2
    keys = jax.random.split(key, 8)
    D = cfg.d_model
    C = cfg.latent_channels
    return {
        "patch_embed": conv_init(keys[0], cfg.patch, cfg.patch, C, D,
                                 dtype=cfg.dtype),
        "pos_embed": trunc_normal(keys[1], (1, n_tokens, D), dtype=cfg.dtype),
        "t_mlp": {
            "fc1": linear_init(keys[2], 256, D, dtype=cfg.dtype),
            "fc2": linear_init(keys[3], D, D, dtype=cfg.dtype),
        },
        "y_embed": trunc_normal(keys[4], (cfg.n_classes + 1, D),
                                dtype=cfg.dtype),  # +1 = CFG null class
        "layers": stack_init(keys[5], cfg.n_layers,
                             lambda k: dit_block_init(k, cfg)),
        "final_ada": {"w": jnp.zeros((D, 2 * D), dtype=cfg.dtype),
                      "b": jnp.zeros((2 * D,), dtype=cfg.dtype)},
        "final_proj": linear_init(keys[6], D, cfg.patch * cfg.patch * C,
                                  std=0.0, dtype=cfg.dtype),
    }


def dit_forward(params: Params, cfg: DiffusionConfig, latents: jnp.ndarray,
                t: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """latents [B,R,R,C]; t [B] in [0,1000); y [B] class ids -> noise pred."""
    B, R, _, C = latents.shape
    p_sz = cfg.patch
    g = R // p_sz

    x = conv2d(params["patch_embed"], latents.astype(cfg.dtype),
               stride=p_sz, padding="VALID").reshape(B, g * g, cfg.d_model)
    pos = params["pos_embed"]
    if pos.shape[1] != g * g:
        # bilinear-resize the learned grid for off-train resolutions
        g0 = int(round(pos.shape[1] ** 0.5))
        grid = pos.reshape(1, g0, g0, -1)
        grid = jax.image.resize(grid, (1, g, g, grid.shape[-1]), "bilinear")
        pos = grid.reshape(1, g * g, -1)
    x = x + pos.astype(x.dtype)

    t_emb = timestep_embedding(t, 256)
    c = linear(params["t_mlp"]["fc2"],
               jax.nn.silu(linear(params["t_mlp"]["fc1"],
                                  t_emb.astype(cfg.dtype))))
    c = c + jnp.take(params["y_embed"], y, axis=0).astype(c.dtype)

    def body(lp, carry, extra):
        return dit_block(lp, carry, extra, cfg)

    x = scan_layers(body, params["layers"], x, extra=c, remat=cfg.remat,
                    remat_policy="dots_no_batch")

    mod = linear(params["final_ada"], jax.nn.silu(c))
    sh, sc = jnp.split(mod[:, None, :], 2, axis=-1)
    x = modulated_layernorm({}, x, sh, sc)
    x = linear(params["final_proj"], x)               # [B, g*g, p*p*C]
    x = x.reshape(B, g, g, p_sz, p_sz, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, R, R, C)
    return x
