"""MoE decoder LM (DeepSeek-V3 / Kimi-K2 style).

Layer stack = `first_dense_layers` dense blocks (unstacked) followed by a
scan over homogeneous MoE blocks. Attention is MLA (deepseek) or GQA (kimi).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import attention as attn
from repro.models import moe
from repro.models.layers import (
    Params,
    embedding,
    embedding_init,
    linear,
    linear_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    stack_init,
)


def _attn_init(key, cfg: LMConfig) -> Params:
    if cfg.mla:
        return attn.mla_init(key, cfg.d_model, cfg.n_heads,
                             q_lora_rank=cfg.q_lora_rank,
                             kv_lora_rank=cfg.kv_lora_rank,
                             qk_nope_dim=cfg.qk_nope_dim,
                             qk_rope_dim=cfg.qk_rope_dim,
                             v_head_dim=cfg.v_head_dim, dtype=cfg.dtype)
    return attn.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.resolved_head_dim, dtype=cfg.dtype)


def _attn_apply(p: Params, x, cfg: LMConfig, angles, impl: str):
    if cfg.mla:
        return attn.mla_attention(p, x, n_heads=cfg.n_heads,
                                  qk_nope_dim=cfg.qk_nope_dim,
                                  qk_rope_dim=cfg.qk_rope_dim,
                                  v_head_dim=cfg.v_head_dim,
                                  kv_lora_rank=cfg.kv_lora_rank,
                                  angles=angles, causal=True, impl=impl)
    return attn.gqa_attention(p, x, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads, angles=angles,
                              causal=True, impl=impl)


def dense_block_init(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "attn": _attn_init(k1, cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, gated=True, bias=False,
                        dtype=cfg.dtype),
    }


def moe_block_init(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "attn": _attn_init(k1, cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "moe": moe.moe_init(k2, cfg),
    }


def moe_lm_init(key, cfg: LMConfig) -> Params:
    ke, kd, km, ko = jax.random.split(key, 4)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    dense_keys = jax.random.split(kd, max(1, cfg.first_dense_layers))
    return {
        "embed": embedding_init(ke, cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "dense_layers": [dense_block_init(k, cfg)
                         for k in dense_keys[: cfg.first_dense_layers]],
        "moe_layers": stack_init(km, n_moe, lambda k: moe_block_init(k, cfg)),
        "final_norm": rmsnorm_init(cfg.d_model, dtype=cfg.dtype),
        "lm_head": linear_init(ko, cfg.d_model, cfg.vocab, bias=False,
                               dtype=cfg.dtype),
    }


def moe_lm_forward(params: Params, cfg: LMConfig, tokens: jnp.ndarray, *,
                   impl: str = "xla", capacity_factor: float = 1.25):
    """tokens [B,S] -> (logits [B,S,V], aux_loss)."""
    S = tokens.shape[1]
    rope_dim = cfg.qk_rope_dim if cfg.mla else cfg.resolved_head_dim
    angles = attn.rope_frequencies(rope_dim, S, cfg.rope_theta)
    x = embedding(params["embed"], tokens)

    for lp in params["dense_layers"]:
        h = _attn_apply(lp["attn"], rmsnorm(lp["attn_norm"], x), cfg,
                        angles, impl)
        x = x + h
        x = x + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], x))

    def body(lp, carry, extra):
        x, aux = carry
        h = _attn_apply(lp["attn"], rmsnorm(lp["attn_norm"], x), cfg,
                        extra, impl)
        x = x + h
        y, metrics = moe.moe_ffn(lp["moe"], rmsnorm(lp["mlp_norm"], x), cfg,
                                 capacity_factor=capacity_factor)
        return (x + y, aux + metrics.aux_loss)

    from repro.models.layers import NO_REMAT
    body_fn = body
    if cfg.remat and not NO_REMAT:
        body_fn = jax.checkpoint(
            body,
            policy=(jax.checkpoint_policies
                    .checkpoint_dots_with_no_batch_dims))

    def step(carry, lp):
        return body_fn(lp, carry, angles), None

    from repro.models.layers import scan_unroll
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["moe_layers"], unroll=scan_unroll())
    x = rmsnorm(params["final_norm"], x)
    logits = linear(params["lm_head"], x)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    return logits, aux / max(1, n_moe)


def moe_lm_loss(params: Params, cfg: LMConfig, tokens, labels, *,
                aux_weight: float = 0.001):
    logits, aux = moe_lm_forward(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).squeeze(-1)
    return jnp.mean(nll) + aux_weight * aux
