from repro.data.scene import CAR, PERSON, Scene, SceneConfig
from repro.data.render import boxes_to_scene, gt_boxes, render_image
from repro.data.dataset import Video, build_video
