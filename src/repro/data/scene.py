"""Procedural 360°-style scene simulator.

Replaces the paper's 50-video YouTube dataset (offline container): objects
of interest (people, cars) move through a 150°x75° panorama with class-
specific dynamics chosen to reproduce the paper's measured statistics —

  * people: waypoint random walks between points-of-interest clusters
    (unstructured motion, frequent direction changes — paper §5.2 notes
    people queries switch orientations more);
  * cars: lane traffic at fixed tilt bands with constant velocities
    (structured motion);
  * spawn/despawn keeps density stationary;
  * the resulting best-orientation dwell times (~5-6 s median) and
    neighbor-accuracy correlation (~0.8) are asserted in
    benchmarks/bench_scene_stats.py against the paper's Figures 3/7/9-11.

Everything is numpy struct-of-arrays; ground truth at any (orientation,
zoom) is exact — the simulator is the oracle the accuracy metrics need.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

PERSON, CAR = 0, 1
CLASS_NAMES = {PERSON: "person", CAR: "car"}


@dataclass
class SceneConfig:
    extent: tuple = (150.0, 75.0)     # degrees (pan, tilt)
    fps: int = 15
    n_people: int = 14
    n_cars: int = 8
    n_poi: int = 3                    # person points-of-interest
    person_speed: float = 1.2         # deg/s mean
    car_speed: float = 10.0           # deg/s mean
    # angular sizes are calibrated against the teacher profiles: at zoom 1
    # (60x30 deg FOV) a median person is ~0.13 apparent (strong models see
    # it, weak ones need zoom); a median car is ~0.12 wide
    person_size: tuple = (2.5, 5.5)   # height range (deg)
    car_size: tuple = (5.0, 9.0)      # width range (deg)
    lane_tilts: tuple = (20.0, 32.0, 44.0)
    seed: int = 0
    churn: float = 0.01               # per-step respawn probability


@dataclass
class Scene:
    cfg: SceneConfig
    t: int = 0
    # struct-of-arrays object state (filled in __post_init__)
    kind: np.ndarray = field(default=None)
    pos: np.ndarray = field(default=None)       # [N, 2] degrees
    vel: np.ndarray = field(default=None)       # [N, 2] deg/s
    size: np.ndarray = field(default=None)      # [N, 2] degrees (w, h)
    oid: np.ndarray = field(default=None)       # [N] unique ids
    active: np.ndarray = field(default=None)    # [N] bool
    waypoint: np.ndarray = field(default=None)  # [N, 2] person targets

    def __post_init__(self):
        cfg = self.cfg
        self.rng = np.random.default_rng(cfg.seed)
        n = cfg.n_people + cfg.n_cars
        self.kind = np.concatenate([
            np.full(cfg.n_people, PERSON), np.full(cfg.n_cars, CAR)])
        self.poi = self.rng.uniform(
            [15, 10], [cfg.extent[0] - 15, cfg.extent[1] - 10],
            (cfg.n_poi, 2))
        self.pos = np.zeros((n, 2))
        self.vel = np.zeros((n, 2))
        self.size = np.zeros((n, 2))
        self.oid = np.arange(n)
        self.active = np.ones(n, bool)
        self.waypoint = np.zeros((n, 2))
        self._next_id = n
        for i in range(n):
            self._spawn(i, initial=True)

    # ------------------------------------------------------------------
    def _spawn(self, i: int, initial: bool = False):
        cfg, rng = self.cfg, self.rng
        if self.kind[i] == PERSON:
            poi = self.poi[rng.integers(cfg.n_poi)]
            self.pos[i] = np.clip(
                poi + rng.normal(0, 8, 2), [1, 1],
                [cfg.extent[0] - 1, cfg.extent[1] - 1])
            self.waypoint[i] = self.poi[rng.integers(cfg.n_poi)]
            speed = max(0.2, rng.normal(cfg.person_speed, 0.4))
            d = self.waypoint[i] - self.pos[i]
            self.vel[i] = speed * d / max(np.linalg.norm(d), 1e-6)
            w = rng.uniform(*cfg.person_size)
            self.size[i] = (w * 0.45, w)          # people are tall
        else:
            lane = rng.choice(cfg.lane_tilts)
            direction = rng.choice([-1.0, 1.0])
            x0 = 0.0 if direction > 0 else cfg.extent[0]
            if initial:
                x0 = rng.uniform(0, cfg.extent[0])
            self.pos[i] = (x0, lane + rng.normal(0, 1.0))
            speed = max(2.0, rng.normal(cfg.car_speed, 2.5))
            self.vel[i] = (direction * speed, 0.0)
            w = rng.uniform(*cfg.car_size)
            self.size[i] = (w, w * 0.45)          # cars are wide
        if not initial:
            self.oid[i] = self._next_id
            self._next_id += 1
        self.active[i] = True

    # ------------------------------------------------------------------
    def step(self):
        """Advance the scene by one frame (1/fps seconds)."""
        cfg, rng = self.cfg, self.rng
        dt = 1.0 / cfg.fps
        self.t += 1
        self.pos += self.vel * dt

        for i in range(self.pos.shape[0]):
            if self.kind[i] == PERSON:
                d = self.waypoint[i] - self.pos[i]
                if np.linalg.norm(d) < 2.0:
                    self.waypoint[i] = self.poi[rng.integers(cfg.n_poi)] \
                        + rng.normal(0, 6, 2)
                    d = self.waypoint[i] - self.pos[i]
                speed = np.linalg.norm(self.vel[i])
                jitter = rng.normal(0, 0.3, 2)
                v = speed * d / max(np.linalg.norm(d), 1e-6) + jitter
                self.vel[i] = v / max(np.linalg.norm(v), 1e-6) * speed
                self.pos[i] = np.clip(self.pos[i], 0, cfg.extent)
                if rng.random() < cfg.churn * dt * cfg.fps:
                    self._spawn(i)
            else:
                out = (self.pos[i, 0] < -3.0
                       or self.pos[i, 0] > cfg.extent[0] + 3.0)
                if out:
                    self._spawn(i)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Copy of the visible-object state at the current frame."""
        m = self.active
        return {
            "kind": self.kind[m].copy(),
            "pos": self.pos[m].copy(),
            "size": self.size[m].copy(),
            "oid": self.oid[m].copy(),
            "t": self.t,
        }

    def unique_ids_in_window(self, frames: list[dict],
                             obj_kind: int) -> set:
        ids = set()
        for f in frames:
            ids.update(int(i) for i, k in zip(f["oid"], f["kind"])
                       if k == obj_kind)
        return ids
