"""Dataset utilities: precomputed accuracy tables + training batches.

`build_video` rolls a Scene forward and materializes, per frame, the
ground-truth view of every orientation cell — the substrate for oracle
baselines, MadEye evaluation, and the per-figure benchmarks. This is the
analogue of the paper running every workload on all 75 orientations of
each video (§2.2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grid import OrientationGrid
from repro.data.render import gt_boxes
from repro.data.scene import CAR, PERSON, Scene, SceneConfig

OBJ_IDS = {"person": PERSON, "car": CAR}


@dataclass
class Video:
    """Precomputed per-frame, per-cell ground truth for one scene."""
    grid: OrientationGrid
    fps: int
    snapshots: list            # [T] scene snapshots
    # gt[t][cell] -> dict(boxes, classes, ids, apparent) at zoom 1
    gt: list
    # gt_zoom[z][t][cell] for zoom levels (1-indexed into zoom_levels)
    gt_zoom: dict

    @property
    def n_frames(self) -> int:
        return len(self.snapshots)


def build_video(grid: OrientationGrid, cfg: SceneConfig, duration_s: float,
                zoom_levels=(1.0, 2.0, 3.0)) -> Video:
    scene = Scene(cfg)
    T = int(duration_s * cfg.fps)
    snapshots, gt_all = [], []
    gt_zoom = {z: [] for z in zoom_levels}
    for t in range(T):
        scene.step()
        snap = scene.snapshot()
        snapshots.append(snap)
        gt_all.append([gt_boxes(snap, grid, c, zoom_levels[0])
                       for c in range(grid.n_cells)])
        for z in zoom_levels:
            if z == zoom_levels[0]:
                gt_zoom[z].append(gt_all[-1])
            else:
                gt_zoom[z].append([gt_boxes(snap, grid, c, z)
                                   for c in range(grid.n_cells)])
    return Video(grid, cfg.fps, snapshots, gt_all, gt_zoom)


def motion_table(video: Video) -> np.ndarray:
    """[T, n_cells] motion proxy: count of objects whose position moved
    within the cell's FOV since the previous frame (Panoptes input)."""
    T, N = video.n_frames, video.grid.n_cells
    out = np.zeros((T, N))
    for t in range(1, T):
        for c in range(N):
            prev_ids = set(video.gt[t - 1][c]["ids"].tolist())
            cur_ids = set(video.gt[t][c]["ids"].tolist())
            out[t, c] = len(cur_ids | prev_ids) - len(cur_ids & prev_ids) \
                + 0.5 * len(cur_ids & prev_ids)
    return out


def largest_object_table(video: Video):
    """([T] size of globally largest object, [T] cell containing it)."""
    T = video.n_frames
    sizes = np.zeros(T)
    cells = np.zeros(T, int)
    for t in range(T):
        best_s, best_c = 0.0, 0
        for c in range(video.grid.n_cells):
            a = video.gt[t][c]["apparent"]
            if a.size and a.max() > best_s:
                best_s, best_c = float(a.max()), c
        sizes[t], cells[t] = best_s, best_c
    return sizes, cells
