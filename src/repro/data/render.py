"""Orientation rendering: scene snapshot -> ground-truth boxes / images.

`gt_boxes` is the exact oracle (what a perfect detector would see at an
orientation + zoom). `render_image` rasterizes a simple but structured
image (class-colored blobs on textured background) for the NN-path tests
and continual-distillation training; it replaces the paper's
equirectangular-to-rectilinear converter — our simulator works directly in
scene degrees so the projection is an axis-aligned crop.
"""
from __future__ import annotations

import numpy as np

from repro.core.grid import OrientationGrid
from repro.data.scene import CAR, PERSON


def fov_window(grid: OrientationGrid, cell: int, zoom: float):
    """(pan_lo, tilt_lo, fov_w, fov_h) of the cell's view at `zoom`."""
    cx, cy = grid.centers[cell]
    fw, fh = grid.fov(zoom)
    return cx - fw / 2, cy - fh / 2, fw, fh


def gt_boxes(snapshot: dict, grid: OrientationGrid, cell: int, zoom: float,
             min_visible: float = 0.25):
    """Objects visible from (cell, zoom) -> normalized image-space boxes.

    Returns dict with boxes [K,4] cxcywh in [0,1], classes [K], ids [K],
    apparent [K] (apparent size = max box side, the detectability driver).
    Objects are kept if >= `min_visible` of their area is inside the FOV.
    """
    x0, y0, fw, fh = fov_window(grid, cell, zoom)
    pos, size = snapshot["pos"], snapshot["size"]

    # object extent in scene degrees
    ox0 = pos[:, 0] - size[:, 0] / 2
    ox1 = pos[:, 0] + size[:, 0] / 2
    oy0 = pos[:, 1] - size[:, 1] / 2
    oy1 = pos[:, 1] + size[:, 1] / 2

    ix0 = np.maximum(ox0, x0)
    ix1 = np.minimum(ox1, x0 + fw)
    iy0 = np.maximum(oy0, y0)
    iy1 = np.minimum(oy1, y0 + fh)
    inter = np.maximum(ix1 - ix0, 0) * np.maximum(iy1 - iy0, 0)
    area = (ox1 - ox0) * (oy1 - oy0)
    vis = inter / np.maximum(area, 1e-9)
    keep = vis >= min_visible

    # clip to FOV and normalize
    bx0 = (ix0[keep] - x0) / fw
    bx1 = (ix1[keep] - x0) / fw
    by0 = (iy0[keep] - y0) / fh
    by1 = (iy1[keep] - y0) / fh
    boxes = np.stack([(bx0 + bx1) / 2, (by0 + by1) / 2,
                      bx1 - bx0, by1 - by0], axis=-1)
    apparent = np.maximum(boxes[:, 2], boxes[:, 3]) if keep.any() else \
        np.zeros(0)
    return {
        "boxes": boxes.reshape(-1, 4),
        "classes": snapshot["kind"][keep],
        "ids": snapshot["oid"][keep],
        "apparent": apparent,
        "visibility": vis[keep],
    }


def boxes_to_scene(boxes: np.ndarray, grid: OrientationGrid, cell: int,
                   zoom: float):
    """Normalized image boxes -> (centers [K,2], sizes [K,2]) in degrees."""
    x0, y0, fw, fh = fov_window(grid, cell, zoom)
    centers = np.stack([x0 + boxes[:, 0] * fw, y0 + boxes[:, 1] * fh], -1)
    sizes = np.stack([boxes[:, 2] * fw, boxes[:, 3] * fh], -1)
    return centers, sizes


_CLASS_COLOR = {PERSON: np.array([0.9, 0.3, 0.2]),
                CAR: np.array([0.2, 0.4, 0.9])}


def render_image(snapshot: dict, grid: OrientationGrid, cell: int,
                 zoom: float, res: int = 64, noise: float = 0.05,
                 rng: np.random.Generator | None = None) -> np.ndarray:
    """Rasterize the orientation view to [res, res, 3] float32 in [0,1]."""
    rng = rng or np.random.default_rng(snapshot["t"])
    gt = gt_boxes(snapshot, grid, cell, zoom)
    # textured background: horizontal gradient + low-freq noise
    yy, xx = np.mgrid[0:res, 0:res] / res
    img = np.stack([0.35 + 0.15 * yy, 0.4 + 0.1 * xx,
                    0.35 + 0.05 * (xx + yy)], axis=-1)
    img += noise * rng.standard_normal((res, res, 3))

    for box, cls, oid in zip(gt["boxes"], gt["classes"], gt["ids"]):
        cx, cy, w, h = box
        px0 = int(np.clip((cx - w / 2) * res, 0, res - 1))
        px1 = int(np.clip((cx + w / 2) * res + 1, 1, res))
        py0 = int(np.clip((cy - h / 2) * res, 0, res - 1))
        py1 = int(np.clip((cy + h / 2) * res + 1, 1, res))
        shade = 0.7 + 0.3 * ((oid * 2654435761) % 97) / 97.0
        img[py0:py1, px0:px1] = _CLASS_COLOR[int(cls)] * shade
    return np.clip(img, 0.0, 1.0).astype(np.float32)
