"""In-scan device metrics: a per-step FleetMetrics pytree from inside
the jit'd episode.

MadEye's accuracy is governed by decisions the step outputs alone can't
explain: did the search shortlist actually contain the oracle-best
orientation (paper §3.3)? how far does the distilled detector's ranking
drift from the teacher's (§3.4)? is the budget sending what it planned?
`step_metrics` answers those *inside* the scanned step — everything it
reads is already on device, so metrics cost a handful of [F, N]
reductions and leave the scan as one more `[E, ...]` output, no extra
host transfers.

Gating: a static `MetricsSpec` rides `FleetRunSpec.metrics` (and the jit
cache key). `metrics=None` / `enabled=False` compiles the *exact*
pre-metrics scan — decisions are bit-identical either way, pinned by
tests/test_obs.py.

Emitted keys (each a per-step [F] array, stacked to [E, F] by the scan;
`METRIC_KEYS` maps the MetricsSpec flag that owns each group):

  ewma_label_mean   mean EWMA search label over visited cells — the
                    controller's own running accuracy estimate
  frames_sent       frames actually shipped this step (sum of `sent`)
  k_send            the budget's planned send count
  n_explored        search cells visited this step
  cells_visited     distinct cells ever visited (exploration coverage)
  shortlist_hit     1.0 when the oracle-best cell (argmax of acc_true
                    over all N*Z windows) is in the candidate shortlist
                    this step — always 1.0 for exhaustive providers
  chosen_rank       1-based oracle-accuracy rank of the chosen
                    orientation among the explored cells at their chosen
                    zooms; 0 on degenerate steps (<2 explored cells or
                    an all-zero oracle row). The in-scan version of
                    benchmarks/bench_rank_quality's chosen-rank metric.
  score_mean        mean predicted accuracy over explored cells
  score_max         max predicted accuracy over explored cells

`chosen_rank` is the acceptance instrument for the ROADMAP's in-scan
distillation item (converging toward 1.0 == detector ranks like the
teacher); `shortlist_hit` is the one for adaptive-K (shrinking K is free
until the hit-rate dips).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

# flag on MetricsSpec -> the FleetMetrics keys it owns
METRIC_KEYS = {
    "ewma": ("ewma_label_mean",),
    "budget": ("frames_sent", "k_send", "n_explored", "cells_visited"),
    "shortlist": ("shortlist_hit",),
    "rank": ("chosen_rank", "score_mean", "score_max"),
}


@dataclass(frozen=True)
class MetricsSpec:
    """Static (hashable, jit-cache-keyed) gate for in-scan metrics.

    The default `MetricsSpec()` turns everything on; flags drop metric
    groups from the scan outputs entirely (the pytree shrinks — nothing
    is computed for a disabled group). `enabled=False` is equivalent to
    passing no spec at all."""
    enabled: bool = True
    ewma: bool = True
    budget: bool = True
    shortlist: bool = True
    rank: bool = True

    def keys(self) -> tuple:
        if not self.enabled:
            return ()
        return tuple(k for flag, keys in METRIC_KEYS.items()
                     if getattr(self, flag) for k in keys)


def step_metrics(spec: MetricsSpec, cfg, provider, state_pre, state_post,
                 obs, out) -> dict:
    """One step's FleetMetrics — a {name: [F] array} pytree.

    Runs inside the episode scan body after `fleet_step`: `state_pre` is
    the controller state the provider observed with (the shortlist is a
    pure function of it, so the candidate set is *recomputed* here
    bit-identically rather than threaded through the provider seam),
    `state_post`/`out` are fleet_step's results, `obs` this step's
    observation tables (for the oracle-best window).
    """
    from repro.core import ewma
    from repro.fleet.step import gather_at_zoom

    m: dict[str, jnp.ndarray] = {}
    f, n = out.explored.shape
    arange_f = jnp.arange(f)

    if spec.ewma:
        lab = ewma.labels(state_post.ewma, delta_weight=cfg.delta_weight)
        seen = state_post.ewma.seen > 0
        m["ewma_label_mean"] = (jnp.where(seen, lab, 0.0).sum(-1)
                                / jnp.maximum(seen.sum(-1), 1))

    if spec.budget:
        from repro.fleet.state import NEVER_VISITED

        m["frames_sent"] = out.sent.sum(-1).astype(jnp.int32)
        m["k_send"] = out.k_send
        m["n_explored"] = out.n_explored
        m["cells_visited"] = jnp.sum(
            state_post.last_visit > NEVER_VISITED, -1).astype(jnp.int32)

    if spec.shortlist:
        z = len(cfg.zoom_levels)
        c = n * z
        acc = jnp.broadcast_to(obs.acc_true, (f, n, z))
        best_cell = jnp.argmax(acc.reshape(f, c), axis=-1) // z
        k = getattr(provider, "shortlist_k", 0)
        if 0 < k < c:
            from repro.fleet.runner import shortlist_windows

            widx = shortlist_windows(cfg, state_pre, provider.nbr8, k)
            kept = widx[:, ::z] // z                    # [F, K/Z] cells
            hit = jnp.any(kept == best_cell[:, None], axis=-1)
        else:
            hit = jnp.ones((f,), bool)
        m["shortlist_hit"] = hit.astype(jnp.float32)

    if spec.rank:
        true_g = gather_at_zoom(obs.acc_true, out.zooms)     # [F, N]
        chosen_val = true_g[arange_f, out.chosen]
        mx = jnp.max(jnp.where(out.explored, true_g, -jnp.inf), -1)
        valid = (out.n_explored >= 2) & (mx > 0)
        rank = 1 + jnp.sum(
            out.explored & (true_g > chosen_val[:, None]), -1)
        m["chosen_rank"] = jnp.where(valid, rank, 0).astype(jnp.int32)
        pred = jnp.where(out.explored, out.pred_acc, 0.0)
        kf = jnp.maximum(out.n_explored, 1).astype(jnp.float32)
        m["score_mean"] = pred.sum(-1) / kf
        m["score_max"] = pred.max(-1)

    return m


# ---------------------------------------------------------------------------
# host-side reductions over the emitted [E, F] metrics
# ---------------------------------------------------------------------------

def median_valid_rank(chosen_rank) -> float:
    """Median of the non-degenerate chosen-rank entries (0 = the step
    was degenerate and is excluded); 0.0 when no step was gradable.
    This is bench_rank_quality's median-rank metric, read directly off
    the emitted FleetMetrics instead of a replay pass."""
    r = np.asarray(chosen_rank).reshape(-1)
    r = r[r > 0]
    return float(np.median(r)) if r.size else 0.0


def summarize_metrics(metrics: dict) -> dict:
    """Reduce stacked [E, F] FleetMetrics to a JSON-native per-camera
    summary dict — what the telemetry event stream and FleetResult
    expose off-device."""
    m = {k: np.asarray(v) for k, v in metrics.items()}
    out: dict = {}
    if "ewma_label_mean" in m:
        out["ewma_label_final"] = m["ewma_label_mean"][-1].tolist()
    if "frames_sent" in m:
        out["frames_sent_total"] = m["frames_sent"].sum(0).tolist()
        out["frames_budget_total"] = m["k_send"].sum(0).tolist()
        out["cells_visited_final"] = m["cells_visited"][-1].tolist()
        out["mean_explored"] = m["n_explored"].mean(0).tolist()
    if "shortlist_hit" in m:
        out["shortlist_hit_rate"] = m["shortlist_hit"].mean(0).tolist()
    if "chosen_rank" in m:
        out["chosen_rank_median"] = [
            median_valid_rank(m["chosen_rank"][:, fi])
            for fi in range(m["chosen_rank"].shape[1])]
        out["score_mean"] = m["score_mean"].mean(0).tolist()
    if "distill_loss" in m:
        # learning runs only (repro.learn): mean loss per camera over
        # the steps it actually updated (-1.0 marks off-cadence/idle)
        loss = m["distill_loss"]
        upd = loss >= 0.0
        n = np.maximum(upd.sum(0), 1)
        out["distill_loss_mean"] = np.where(
            upd.any(0), (loss * upd).sum(0) / n, -1.0).tolist()
        out["distill_update_steps"] = upd.sum(0).tolist()
        out["distill_lr_final"] = m["distill_lr"][-1].tolist()
    return out
