"""Fleet telemetry: in-scan device metrics, host traces, event streams.

Three layers, one per kind of blindness the fleet pipeline had:

  metrics.py  `MetricsSpec`-gated FleetMetrics computed *inside* the
              jit'd episode scan (shortlist hit-rate, chosen-vs-oracle
              rank, EWMA labels, budget counters) — per-step [E, F]
              device outputs, zero cost when off
  trace.py    host span API -> Chrome trace JSON (build / compile /
              steady-state / bench-leg phases; chrome://tracing,
              Perfetto) with optional jax.profiler annotation
  events.py   FleetResult -> chunked JSONL event stream with per-camera
              health summaries (`serve --fleet N --telemetry PATH|-`)

This package never imports repro.fleet at module scope (the runner
imports metrics into the scan body), so it stays import-cycle-free and
usable from any layer.
"""
from repro.obs.metrics import (
    METRIC_KEYS,
    MetricsSpec,
    median_valid_rank,
    step_metrics,
    summarize_metrics,
)
from repro.obs.trace import (
    Tracer,
    activate,
    active_tracer,
    deactivate,
    span,
    tracing,
)
from repro.obs.events import (
    SCHEMA_VERSION,
    episode_events,
    read_events,
    validate_event,
    write_events,
)

__all__ = [
    "METRIC_KEYS",
    "MetricsSpec",
    "median_valid_rank",
    "step_metrics",
    "summarize_metrics",
    "Tracer",
    "activate",
    "active_tracer",
    "deactivate",
    "span",
    "tracing",
    "SCHEMA_VERSION",
    "episode_events",
    "read_events",
    "validate_event",
    "write_events",
]
