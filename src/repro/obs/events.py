"""Streaming episode events: FleetResult -> JSONL telemetry.

The first concrete piece of the ROADMAP serving tier: a fleet episode
becomes a stream of JSON-lines events a dashboard / alerting client can
tail (`serve --fleet N --telemetry PATH|-`). Device->host transfer is
amortized by slicing the episode's existing stacked `[E, ...]` outputs
in chunks of steps — one transfer per chunk per leaf, never per step.

Schema (one JSON object per line, `schema` = SCHEMA_VERSION on every
event; `validate_event` pins the required keys):

  {"event": "run_start", "schema": 1, "spec": {...FleetRunSpec...},
   "n_cameras": F, "n_steps": E, "metrics": true|false}

  {"event": "steps", "schema": 1, "step0": s, "step1": s+k,
   "acc_mean": float,            # fleet-mean oracle acc over the chunk
   "frames_sent": int,           # fleet-wide frames shipped in the chunk
   "cameras": {                  # per-camera health summary, [F] lists
      "acc_mean": [...], "frames_sent": [...], "n_explored_mean": [...],
      "health": ["ok"|"idle"|"lagging", ...],
      # with FleetMetrics enabled on the run, additionally:
      "ewma_label": [...], "shortlist_hit_rate": [...],
      "chosen_rank_median": [...],
      # and on distillation runs (FleetRunSpec.distill, repro.learn):
      "distill_loss": [...], "distill_lr": [...]}}

  {"event": "run_end", "schema": 1, "accuracy": float,
   "frames_sent_total": int, "timings": {...},
   "camera_steps_per_s": float, "metrics_summary": {...}|null}

Health classification (documented, deterministic): a camera is "idle"
when it shipped no frame in the chunk, "lagging" when its chunk-mean
oracle accuracy falls below half the fleet chunk mean, else "ok".
"""
from __future__ import annotations

import dataclasses
import json
import sys

import numpy as np

SCHEMA_VERSION = 1

REQUIRED_KEYS = {
    "run_start": ("schema", "spec", "n_cameras", "n_steps", "metrics"),
    "steps": ("schema", "step0", "step1", "acc_mean", "frames_sent",
              "cameras"),
    "run_end": ("schema", "accuracy", "frames_sent_total", "timings",
                "camera_steps_per_s", "metrics_summary"),
}
_CAMERA_KEYS = ("acc_mean", "frames_sent", "n_explored_mean", "health")


def validate_event(ev: dict) -> dict:
    """Raise ValueError unless `ev` carries its event type's required
    keys (steps events additionally pin the per-camera summary keys).
    Returns the event for chaining."""
    kind = ev.get("event")
    if kind not in REQUIRED_KEYS:
        raise ValueError(f"unknown event type {kind!r}; expected one of "
                         f"{sorted(REQUIRED_KEYS)}")
    missing = [k for k in REQUIRED_KEYS[kind] if k not in ev]
    if kind == "steps":
        missing += [f"cameras.{k}" for k in _CAMERA_KEYS
                    if k not in ev.get("cameras", {})]
    if missing:
        raise ValueError(f"{kind} event missing keys: {missing}")
    return ev


def _health(acc_mean: np.ndarray, sent: np.ndarray) -> list:
    fleet = float(acc_mean.mean())
    out = []
    for a, s in zip(acc_mean, sent):
        if s == 0:
            out.append("idle")
        elif fleet > 0 and a < 0.5 * fleet:
            out.append("lagging")
        else:
            out.append("ok")
    return out


def episode_events(result, *, chunk: int = 16):
    """Yield telemetry events for a completed fleet episode.

    `result` is a repro.fleet.FleetResult that still carries its device
    outputs (`result.out` — run_fleet's return does; a JSON-round-
    tripped result does not and raises). Chunking slices the stacked
    [E, ...] device arrays `chunk` steps at a time, so each leaf incurs
    one device->host copy per chunk."""
    from repro.obs.metrics import median_valid_rank, summarize_metrics

    if result.out is None:
        raise ValueError("episode_events needs the device outputs; this "
                         "FleetResult was stripped (JSON round trip?)")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    e, f = result.n_steps, result.n_cameras
    metrics = getattr(result, "metrics", None)
    try:
        spec_json = json.loads(result.spec.to_json())
    except TypeError:
        # specs built from in-memory objects (the tables provider's
        # prebuilt video/tables/trace ride through provider_kwargs)
        # aren't JSON-round-trippable — telemetry still names them
        stripped = dataclasses.replace(result.spec, provider_kwargs={})
        spec_json = json.loads(stripped.to_json())
        spec_json["provider_kwargs"] = {
            k: f"<in-memory {type(v).__name__}>"
            for k, v in result.spec.provider_kwargs.items()}
    yield validate_event({
        "event": "run_start", "schema": SCHEMA_VERSION,
        "spec": spec_json,
        "n_cameras": f, "n_steps": e, "metrics": metrics is not None})

    for s0 in range(0, e, chunk):
        s1 = min(s0 + chunk, e)
        # one device->host copy per leaf per chunk
        acc = np.asarray(result.out.acc_chosen[s0:s1], np.float32)
        sent = np.asarray(result.out.sent[s0:s1])
        nexp = np.asarray(result.out.n_explored[s0:s1], np.float32)
        cam_acc = acc.mean(0)
        cam_sent = sent.sum((0, 2)).astype(int)
        cameras = {
            "acc_mean": [round(float(a), 4) for a in cam_acc],
            "frames_sent": cam_sent.tolist(),
            "n_explored_mean": [round(float(x), 2) for x in nexp.mean(0)],
            "health": _health(cam_acc, cam_sent),
        }
        if metrics is not None:
            if "ewma_label_mean" in metrics:
                lab = np.asarray(metrics["ewma_label_mean"][s1 - 1])
                cameras["ewma_label"] = [round(float(x), 4) for x in lab]
            if "shortlist_hit" in metrics:
                hit = np.asarray(metrics["shortlist_hit"][s0:s1])
                cameras["shortlist_hit_rate"] = [
                    round(float(x), 4) for x in hit.mean(0)]
            if "chosen_rank" in metrics:
                rank = np.asarray(metrics["chosen_rank"][s0:s1])
                cameras["chosen_rank_median"] = [
                    median_valid_rank(rank[:, fi]) for fi in range(f)]
            if "distill_loss" in metrics:
                # learning runs (repro.learn) — per-camera mean loss
                # over this chunk's actual updates (-1.0 = none)
                loss = np.asarray(metrics["distill_loss"][s0:s1],
                                  np.float32)
                upd = loss >= 0.0
                cameras["distill_loss"] = [
                    round(float((loss[:, fi] * upd[:, fi]).sum()
                                / max(upd[:, fi].sum(), 1))
                          if upd[:, fi].any() else -1.0, 5)
                    for fi in range(f)]
                lr = np.asarray(metrics["distill_lr"][s1 - 1])
                cameras["distill_lr"] = [
                    round(float(x), 6) for x in lr]
        yield validate_event({
            "event": "steps", "schema": SCHEMA_VERSION,
            "step0": s0, "step1": s1,
            "acc_mean": round(float(acc.mean()), 4),
            "frames_sent": int(sent.sum()),
            "cameras": cameras})

    yield validate_event({
        "event": "run_end", "schema": SCHEMA_VERSION,
        "accuracy": result.accuracy,
        "frames_sent_total": int(sum(result.frames_sent)),
        "timings": result.timings,
        "camera_steps_per_s": result.camera_steps_per_s,
        "metrics_summary": (None if metrics is None
                            else summarize_metrics(metrics))})


def write_events(events, path: str) -> int:
    """Write an event iterable as JSON lines to `path` ("-" = stdout;
    files are opened in append mode — telemetry is a log). Returns the
    number of events written."""
    n = 0
    if path == "-":
        for ev in events:
            sys.stdout.write(json.dumps(ev) + "\n")
            n += 1
        sys.stdout.flush()
        return n
    with open(path, "a") as fh:
        for ev in events:
            fh.write(json.dumps(ev) + "\n")
            n += 1
    return n


def read_events(path: str) -> list:
    """Load + validate a telemetry JSONL file back into event dicts."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(validate_event(json.loads(line)))
    return out
