"""Host-side structured traces: lightweight spans -> Chrome trace JSON.

The fleet pipeline's wall time hides in phases the step outputs can't
see — provider build, jit trace + XLA compile, steady-state scan, each
bench leg. `Tracer` records named spans (a `with span("fleet/compile")`
context) with microsecond timestamps and exports the Chrome trace event
format, so a whole benchmark run opens directly in `chrome://tracing` /
Perfetto and "is the 9.5x coming from patchify or the forward" becomes a
zoom, not a printf hunt.

Design constraints:

  * zero overhead when no tracer is active: the module-level `span()`
    returns a shared nullcontext, so instrumented library code
    (prepare_fleet_run, the kernels' ops entry points, the engine shims)
    costs nothing in normal runs;
  * spans on ops entry points measure *host* time (trace/dispatch) —
    inside jit that is trace+lowering cost, which is exactly the
    compile-phase attribution the ROADMAP's perf items need;
  * optional `jax_profiler=True` additionally opens a
    `jax.profiler.TraceAnnotation` per span so spans line up with
    device timelines captured by `jax.profiler.trace`.

Usage:

    from repro.obs.trace import span, tracing

    with tracing("run_trace.json"):          # activate + save on exit
        with span("build", provider="scene"):
            ...
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager, nullcontext

_NULL = nullcontext()


class Tracer:
    """Span recorder exporting the Chrome trace event format."""

    def __init__(self, *, jax_profiler: bool = False):
        self.events: list[dict] = []
        self.jax_profiler = jax_profiler
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()

    @contextmanager
    def span(self, name: str, **args):
        """Record one complete ("ph": "X") span around the with-body.
        Extra kwargs land in the event's `args` (must be JSON-native)."""
        ann = None
        if self.jax_profiler:
            import jax

            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        start = time.perf_counter()
        try:
            yield self
        finally:
            dur = time.perf_counter() - start
            if ann is not None:
                ann.__exit__(None, None, None)
            ev = {"name": name, "ph": "X", "pid": os.getpid(),
                  "tid": threading.get_ident(),
                  "ts": (start - self._t0) * 1e6, "dur": dur * 1e6}
            if args:
                ev["args"] = {k: v if isinstance(
                    v, (int, float, str, bool, type(None))) else str(v)
                    for k, v in args.items()}
            with self._lock:
                self.events.append(ev)

    def to_chrome(self) -> dict:
        """The chrome://tracing / Perfetto JSON object."""
        with self._lock:
            return {"traceEvents": list(self.events),
                    "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ---------------------------------------------------------------------------
# module-level activation (what library code talks to)
# ---------------------------------------------------------------------------

_active: Tracer | None = None


def activate(tracer: Tracer | None = None, **kwargs) -> Tracer:
    """Install `tracer` (or a fresh Tracer(**kwargs)) as the active one."""
    global _active
    _active = tracer if tracer is not None else Tracer(**kwargs)
    return _active


def deactivate() -> Tracer | None:
    """Remove and return the active tracer."""
    global _active
    t, _active = _active, None
    return t


def active_tracer() -> Tracer | None:
    return _active


def span(name: str, **args):
    """Span on the active tracer — a shared no-op context when none is
    active, so instrumentation in hot entry points is free by default."""
    t = _active
    if t is None:
        return _NULL
    return t.span(name, **args)


@contextmanager
def tracing(path: str | None = None, *, jax_profiler: bool = False):
    """Activate a fresh tracer for the with-body; save Chrome trace JSON
    to `path` on exit (when given) and restore the previous tracer."""
    prev = _active
    t = activate(Tracer(jax_profiler=jax_profiler))
    try:
        yield t
    finally:
        globals()["_active"] = prev
        if path is not None:
            t.save(path)
