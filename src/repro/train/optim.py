"""Hand-written optimizers (no optax): AdamW + SGD with pytree masking.

Masking is load-bearing for MadEye's continual learning — only the
detector's head params get Adam state (paper: only the final 3 prediction
layers are fine-tuned), which cuts optimizer memory ~97% and keeps the
frozen backbone weights bit-identical for the camera-side cache.

Optimizer states are plain pytrees, so ZeRO-style sharding over the data
axis is a PartitionSpec away (distributed/sharding.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Params
    nu: Params


def _mask_like(params: Params, mask: Params | None) -> Params:
    if mask is None:
        return jax.tree.map(lambda _: True, params)
    return mask


def adamw_init(params: Params, mask: Params | None = None) -> AdamState:
    m = _mask_like(params, mask)
    zeros = jax.tree.map(
        lambda p, keep: jnp.zeros_like(p) if keep else jnp.zeros((), p.dtype),
        params, m)
    return AdamState(jnp.zeros((), jnp.int32), zeros, zeros)


def adamw_update(params: Params, grads: Params, state: AdamState, *,
                 lr: float | jnp.ndarray = 1e-3, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0, mask: Params | None = None,
                 grad_clip: float | None = 1.0):
    """Returns (new_params, new_state). Masked leaves pass through."""
    m = _mask_like(params, mask)
    step = state.step + 1

    if grad_clip is not None:
        flat = [jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g, keep in zip(jax.tree.leaves(grads), jax.tree.leaves(m))
                if True]
        gnorm = jnp.sqrt(sum(flat))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, keep):
        if not keep:
            return p, mu, nu
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu, m)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamState(step, new_mu, new_nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Params


def sgd_init(params: Params) -> SGDState:
    return SGDState(jnp.zeros((), jnp.int32),
                    jax.tree.map(jnp.zeros_like, params))


def sgd_update(params: Params, grads: Params, state: SGDState, *,
               lr: float = 0.1, momentum: float = 0.9):
    def upd(p, g, m):
        m = momentum * m + g.astype(m.dtype)
        return (p.astype(jnp.float32) - lr * m.astype(jnp.float32)
                ).astype(p.dtype), m
    out = jax.tree.map(upd, params, grads, state.momentum)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(state.step + 1, new_m)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — Shazeer & Stern 2018)
# ---------------------------------------------------------------------------
# The memory answer for trillion-parameter MoE training: a [n, m] weight
# keeps row/col second-moment factors (n + m floats) instead of n*m, so
# optimizer state is ~0.1% of AdamW's. No first moment by default.

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Params       # row factors  (shape[:-1])
    vc: Params       # col factors  (shape[:-2] + shape[-1:])
    v: Params        # full second moment for rank<2 leaves


def adafactor_init(params: Params) -> AdafactorState:
    def row(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2
                else jnp.zeros((), jnp.float32))

    def col(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if p.ndim >= 2 else jnp.zeros((), jnp.float32))

    def full(p):
        return (jnp.zeros(p.shape, jnp.float32) if p.ndim < 2
                else jnp.zeros((), jnp.float32))

    return AdafactorState(jnp.zeros((), jnp.int32),
                          jax.tree.map(row, params),
                          jax.tree.map(col, params),
                          jax.tree.map(full, params))


def adafactor_update(params: Params, grads: Params, state: AdafactorState,
                     *, lr: float = 1e-3, decay: float = 0.8,
                     eps: float = 1e-30, clip_rms: float = 1.0):
    step = state.step + 1
    beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

    def upd(p, g, vr, vc, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if p.ndim >= 2:
            vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True), eps)
            update = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                            + 1e-12)
        else:
            v = beta * v + (1 - beta) * g2
            update = g32 / (jnp.sqrt(v) + 1e-12)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_rms)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), vr, vc, v

    out = jax.tree.map(upd, params, grads, state.vr, state.vc, state.v)
    def pick(i):
        return jax.tree.map(lambda t: t[i], out,
                            is_leaf=lambda t: isinstance(t, tuple))

    return pick(0), AdafactorState(step, pick(1), pick(2), pick(3))


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
