"""Elastic re-sharding: resume a checkpoint on a different mesh.

When nodes die (or capacity grows), the job restarts with a different
device count. Checkpoints store *global* arrays (or per-host shards of
them); `reshard` re-lays a pytree out for a new mesh by building new
global arrays from the old values with the new sharding. All data movement
is delegated to jax.device_put with the target sharding — GSPMD emits the
minimal collective/DMA schedule.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def reshard(tree, shardings_tree):
    """device_put every leaf onto its new NamedSharding."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings_tree)


def shrink_mesh(mesh: Mesh, failed_axis: str, keep: int) -> Mesh:
    """Rebuild a mesh with `keep` slots along one axis (node loss)."""
    axis_idx = mesh.axis_names.index(failed_axis)
    shape = list(mesh.devices.shape)
    if keep >= shape[axis_idx]:
        return mesh
    index = [slice(None)] * len(shape)
    index[axis_idx] = slice(0, keep)
    return Mesh(mesh.devices[tuple(index)], mesh.axis_names)


def valid_submesh_sizes(n_devices: int, model_parallel: int) -> list[int]:
    """Data-parallel widths that evenly use the surviving devices."""
    out = []
    for dp in range(1, n_devices // model_parallel + 1):
        if dp * model_parallel <= n_devices:
            out.append(dp)
    return out


def rebalance_batch(global_batch: int, old_dp: int, new_dp: int) -> int:
    """Keep per-replica batch constant when the DP width changes; the
    caller rescales accumulation steps to preserve the optimizer's
    effective batch."""
    per_replica = global_batch // old_dp
    return per_replica * new_dp
