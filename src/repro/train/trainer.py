"""pjit train-step factory for every architecture family.

`make_train_step(arch)` returns (init_fn, step_fn, input_specs) where
step_fn(params, opt_state, batch, key) -> (params', opt_state', metrics)
is pure and pjit-able — launch/train.py and launch/dryrun.py wrap it with
in/out shardings from distributed/sharding.py.

Microbatching (gradient accumulation) uses lax.scan over the leading
microbatch axis so remat + collective overlap still apply per microbatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import (
    DetectorConfig,
    DiffusionConfig,
    LMConfig,
    VisionConfig,
)
from repro.models import detector as det_mod
from repro.models import diffusion as diff
from repro.models import dit as dit_mod
from repro.models import mmdit as mmdit_mod
from repro.models import moe_lm, swin as swin_mod, transformer, vit as vit_mod
from repro.models.mmdit import TXT_TOKENS
from repro.train import optim


@dataclass(frozen=True)
class TrainStep:
    init_params: Callable        # key -> params
    init_opt: Callable           # params -> opt_state
    step: Callable               # (params, opt, batch, key) -> (p, o, metrics)
    batch_spec: Callable         # (global_batch, seq/img) -> dict of SDS


def _loss_for(cfg) -> Callable:
    if isinstance(cfg, LMConfig):
        if cfg.moe_experts:
            return lambda p, b, k: moe_lm.moe_lm_loss(
                p, cfg, b["tokens"], b["labels"])
        return lambda p, b, k: transformer.lm_loss(
            p, cfg, b["tokens"], b["labels"])
    if isinstance(cfg, VisionConfig):
        if cfg.swin:
            return lambda p, b, k: swin_mod.swin_loss(
                p, cfg, b["images"], b["labels"])
        return lambda p, b, k: vit_mod.vit_loss(
            p, cfg, b["images"], b["labels"])
    if isinstance(cfg, DiffusionConfig):
        if cfg.is_mmdit:
            return lambda p, b, k: diff.rf_train_loss(
                p, cfg, b["latents"], b["txt_emb"], k)
        return lambda p, b, k: diff.dit_train_loss(
            p, cfg, b["latents"], b["labels"], k)
    if isinstance(cfg, DetectorConfig):
        return lambda p, b, k: det_mod.detector_loss(
            p, cfg, b["images"], b["gt_boxes"], b["gt_classes"],
            b["gt_valid"])
    raise TypeError(type(cfg))


def _init_for(cfg) -> Callable:
    if isinstance(cfg, LMConfig):
        return (lambda k: moe_lm.moe_lm_init(k, cfg)) if cfg.moe_experts \
            else (lambda k: transformer.lm_init(k, cfg))
    if isinstance(cfg, VisionConfig):
        return (lambda k: swin_mod.swin_init(k, cfg)) if cfg.swin \
            else (lambda k: vit_mod.vit_init(k, cfg))
    if isinstance(cfg, DiffusionConfig):
        return (lambda k: mmdit_mod.mmdit_init(k, cfg)) if cfg.is_mmdit \
            else (lambda k: dit_mod.dit_init(k, cfg))
    if isinstance(cfg, DetectorConfig):
        return lambda k: det_mod.detector_init(k, cfg)
    raise TypeError(type(cfg))


def batch_specs(cfg, shape, *, microbatches: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for the training batch (dry-run input)."""
    B = shape.global_batch
    assert B % microbatches == 0
    mb = B // microbatches
    lead = (microbatches, mb) if microbatches > 1 else (B,)

    def sds(s, dt):
        return jax.ShapeDtypeStruct(lead + s, dt)

    if isinstance(cfg, LMConfig):
        S = shape.seq_len
        return {"tokens": sds((S,), jnp.int32), "labels": sds((S,), jnp.int32)}
    if isinstance(cfg, VisionConfig):
        r = shape.img_res
        return {"images": sds((r, r, 3), jnp.float32),
                "labels": sds((), jnp.int32)}
    if isinstance(cfg, DiffusionConfig):
        r = (cfg.latent_res if cfg.latent_res else shape.img_res // 8)
        if shape.img_res and cfg.latent_res:
            # latent res scales with the shape's image resolution
            r = cfg.latent_res * shape.img_res // cfg.img_res
        d = {"latents": sds((r, r, cfg.latent_channels), jnp.float32)}
        if cfg.is_mmdit:
            d["txt_emb"] = sds((TXT_TOKENS, cfg.cond_dim), jnp.float32)
        else:
            d["labels"] = sds((), jnp.int32)
        return d
    if isinstance(cfg, DetectorConfig):
        r = cfg.img_res
        N = cfg.max_boxes
        return {"images": sds((r, r, 3), jnp.float32),
                "gt_boxes": sds((N, 4), jnp.float32),
                "gt_classes": sds((N,), jnp.int32),
                "gt_valid": sds((N,), jnp.bool_)}
    raise TypeError(type(cfg))


def make_train_step(cfg, *, lr: float = 1e-4, weight_decay: float = 0.01,
                    microbatches: int = 1, grad_clip: float = 1.0,
                    param_mask=None, optimizer: str = "adamw") -> TrainStep:
    """optimizer: 'adamw' | 'adafactor' — adafactor's factored second
    moment is the memory answer for the trillion-param MoE cells (state
    ~0.1% of AdamW's 8 bytes/param)."""
    loss_fn = _loss_for(cfg)
    init_fn = _init_for(cfg)

    def init_opt(params):
        if optimizer == "adafactor":
            return optim.adafactor_init(params)
        return optim.adamw_init(params, param_mask)

    def step(params, opt_state, batch, key):
        if microbatches > 1:
            def micro(carry, xs):
                gsum, i = carry
                mb, mk = xs
                loss, g = jax.value_and_grad(loss_fn)(params, mb, mk)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, i + 1), loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            keys = jax.random.split(key, microbatches)
            from repro.models.layers import scan_unroll
            (gsum, _), losses = jax.lax.scan(
                micro, (zeros, 0), (batch, keys), unroll=scan_unroll())
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, key)

        if optimizer == "adafactor":
            params, opt_state = optim.adafactor_update(
                params, grads, opt_state, lr=lr)
        else:
            params, opt_state = optim.adamw_update(
                params, grads, opt_state, lr=lr, weight_decay=weight_decay,
                mask=param_mask, grad_clip=grad_clip)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return TrainStep(
        init_params=init_fn,
        init_opt=init_opt,
        step=step,
        batch_spec=partial(batch_specs, cfg, microbatches=microbatches),
    )
