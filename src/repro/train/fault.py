"""Fault tolerance bookkeeping: heartbeats, stragglers, restart decisions.

The control plane a 1000-node job needs, in simulation-testable form:

  * HeartbeatTable — hosts report per-step completion times; missing
    heartbeats past `dead_after_s` mark a host dead;
  * straggler detection — per-step deadline = quantile(history) *
    tolerance; hosts persistently above it get flagged for replacement
    (slow HBM, thermal throttling, failing NIC are the usual culprits);
  * RestartPolicy — decides between in-place continue, elastic shrink
    (train/elastic.py), or full restart from the last checkpoint
    (train/checkpoint.py), with exponential backoff on repeated failures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class HeartbeatTable:
    n_hosts: int
    dead_after_s: float = 60.0
    last_seen: np.ndarray = field(default=None)
    step_times: dict = field(default_factory=dict)   # host -> list[float]
    window: int = 50

    def __post_init__(self):
        now = time.monotonic()
        if self.last_seen is None:
            self.last_seen = np.full(self.n_hosts, now)

    def beat(self, host: int, step_time_s: float,
             now: float | None = None):
        now = time.monotonic() if now is None else now
        self.last_seen[host] = now
        hist = self.step_times.setdefault(host, [])
        hist.append(step_time_s)
        if len(hist) > self.window:
            hist.pop(0)

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h in range(self.n_hosts)
                if now - self.last_seen[h] > self.dead_after_s]

    def stragglers(self, tolerance: float = 1.5,
                   min_samples: int = 10) -> list[int]:
        """Hosts whose median step time exceeds tolerance x fleet median."""
        medians = {}
        for h, hist in self.step_times.items():
            if len(hist) >= min_samples:
                medians[h] = float(np.median(hist))
        if len(medians) < 2:
            return []
        fleet = float(np.median(list(medians.values())))
        return [h for h, m in medians.items() if m > tolerance * fleet]


@dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_base_s: float = 5.0
    restarts: int = 0

    def decide(self, n_dead: int, n_total: int,
               model_parallel: int) -> str:
        """-> 'continue' | 'elastic_shrink' | 'full_restart' | 'abort'."""
        if n_dead == 0:
            return "continue"
        if self.restarts >= self.max_restarts:
            return "abort"
        surviving = n_total - n_dead
        # elastic shrink only if the surviving mesh keeps TP groups whole
        if surviving % model_parallel == 0 and surviving > 0:
            return "elastic_shrink"
        return "full_restart"

    def backoff_s(self) -> float:
        self.restarts += 1
        return self.backoff_base_s * (2 ** min(self.restarts - 1, 6))


def deadline_for_step(history_s: list, quantile: float = 0.99,
                      tolerance: float = 2.0, floor_s: float = 1.0) -> float:
    """Per-step watchdog deadline from recent history (straggler
    mitigation: steps past it trigger within-step work re-dispatch or a
    checkpoint-skip of the slow host's shard)."""
    if not history_s:
        return floor_s * tolerance
    q = float(np.quantile(np.asarray(history_s), quantile))
    return max(q * tolerance, floor_s)
