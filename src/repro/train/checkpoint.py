"""Fault-tolerant sharded checkpointing (msgpack + manifest + atomic rename).

Design for 1000+ nodes:
  * each host writes only its local shard slices (`save_sharded` takes the
    process's addressable slice of every array) into its own file —
    no cross-host traffic at save time;
  * a manifest (JSON) records the pytree structure, global shapes, dtypes
    and the mesh the checkpoint was laid out for — restore can re-shard
    onto a different mesh (train/elastic.py);
  * writes go to `<dir>.tmp-<step>` then os.replace() — a crash mid-save
    never corrupts the last good checkpoint;
  * `latest_step` scans for the newest complete manifest, so restart after
    node failure resumes from the last durable step.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save(ckpt_dir: str, step: int, tree, *, process_index: int = 0,
         extra: dict | None = None) -> str:
    """Atomically write one checkpoint. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{process_index}"
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    paths = _tree_paths(tree)
    shard_file = os.path.join(tmp, f"shard_{process_index:05d}.msgpack")
    payload = {}
    meta = {}
    for p, leaf in zip(paths, leaves):
        arr = np.asarray(leaf)
        payload[p] = arr.tobytes()
        meta[p] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(shard_file, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))

    manifest = {
        "step": step,
        "paths": paths,
        "meta": meta,
        "treedef": str(treedef),
        "n_processes": jax.process_count(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    # atomic publish (single-host semantics; multi-host runs rendezvous
    # in launch/train.py before the coordinator renames)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, process_index: int = 0):
    """Restore into the structure of `like` (a pytree of arrays/SDS)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, MANIFEST)) as f:
        manifest = json.load(f)
    shard_file = os.path.join(final, f"shard_{process_index:05d}.msgpack")
    with open(shard_file, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)

    leaves, treedef = _flatten(like)
    paths = _tree_paths(like)
    out = []
    for p, leaf in zip(paths, leaves):
        m = manifest["meta"][p]
        arr = np.frombuffer(payload[p], dtype=m["dtype"]).reshape(m["shape"])
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest


def prune_old(ckpt_dir: str, keep: int = 3):
    """Keep the newest `keep` checkpoints (bounded disk on long runs)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and ".tmp" not in n)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
