"""Gradient compression with error feedback (distributed optimization).

Cross-pod links (DCN) are an order of magnitude slower than intra-pod ICI,
so the `pod` axis all-reduce is the wire to compress. We use int8
uniform quantization with per-tensor scale + local error feedback
(Seide et al. / EF-SGD): the quantization residual is added back into the
next step's gradient, preserving convergence (the compressor is a
contraction).

Usage inside a shard_map'd train step:

    g_q, scale, state = compress(g, state)
    g_sum = jax.lax.psum(dequantize(g_q, scale), axis_name="pod")

The int8 payload cuts cross-pod bytes 4x vs f32 / 2x vs bf16.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: jnp.ndarray      # same shape as the gradient leaf


def init_ef(grad_like) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grad_like))


def quantize_int8(x: jnp.ndarray):
    """-> (q int8, scale f32[])."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback compression of one gradient leaf.

    Returns (q, scale, new_err) with g + err = deq(q, scale) + new_err.
    """
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compress(grads, state: EFState):
    """Pytree-wise EF compression. Returns (qs, scales, new_state)."""
    out = jax.tree.map(compress_leaf, grads, state.error)
    qs = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[2], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return qs, scales, EFState(errs)


def decompress(qs, scales):
    return jax.tree.map(dequantize_int8, qs, scales)


def crosspod_allreduce_compressed(grads, state: EFState, *,
                                  axis_name: str = "pod"):
    """EF-compressed psum over the slow axis (call inside shard_map).

    The int8 payload crosses the wire; the psum of dequantized values is
    mathematically a sum of per-pod quantized gradients, each pod's
    quantization error staying in its local EF accumulator.
    """
    qs, scales, state = compress(grads, state)
    deq = decompress(qs, scales)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), deq)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(lambda g: g / n, summed)
    return mean, state
