"""End-to-end adaptive serving with the REAL neural approximation model.

    PYTHONPATH=src python examples/adaptive_serving.py

Unlike quickstart.py (analytic approximation proxies), this drives the
actual detector network through the batched InferenceEngine: every
timestep the explored orientations are rendered to images, scored by the
NN in ONE batch (the TPU-native pattern — serving/engine.py), ranked, and
the top-k shipped. The detector is first distilled from the yolov4
teacher for a few steps so its counts are meaningful.

REPRO_EX_DURATION / REPRO_EX_STEPS shrink the scene and the distillation
phase (the CI smoke test runs this as a subprocess with tiny overrides).
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import DEFAULT_GRID, MadEyeController, Observation, Query, \
    Workload
from repro.core import continual
from repro.core.distill import teacher_labels
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video, render_image
from repro.data.render import boxes_to_scene
from repro.models import detector as det
from repro.serving import NetworkTrace, detection_tables, \
    evaluate_selection, workload_acc_table
from repro.serving.engine import InferenceEngine

GRID = DEFAULT_GRID
RES = 64


def distill_detector(cfg, video, tables, key,
                     steps=int(os.environ.get("REPRO_EX_STEPS", "100"))):
    """Bootstrap fine-tuning (paper §3.2 initial phase, abbreviated)."""
    params = det.detector_init(key, cfg)
    opt = continual.init_finetune(params)
    rng = np.random.default_rng(0)
    print("  distilling detector from yolov4 teacher...")
    for step in range(steps):
        ts = rng.integers(0, video.n_frames, 8)
        cells = rng.integers(0, GRID.n_cells, 8)
        imgs, bxs, cls, vld = [], [], [], []
        for t, c in zip(ts, cells):
            imgs.append(render_image(video.snapshots[t], GRID, int(c), 1.0,
                                     res=RES))
            d = tables[("yolov4", "person")].dets[1.0][t][int(c)]
            tgt = teacher_labels([d["boxes"]],
                                 [np.zeros(len(d["boxes"]), int)],
                                 cfg.max_boxes)
            bxs.append(tgt.boxes[0])
            cls.append(tgt.classes[0])
            vld.append(tgt.valid[0])
        params, opt, loss = continual.finetune_step(
            params, opt, cfg, jnp.asarray(np.stack(imgs)),
            jnp.asarray(np.stack(bxs)), jnp.asarray(np.stack(cls)),
            jnp.asarray(np.stack(vld)), lr=3e-3)
        if step % 25 == 0:
            print(f"    step {step:3d} distill loss {float(loss):.3f}")
    return params


def main():
    key = jax.random.PRNGKey(0)
    workload = Workload((Query("yolov4", "person", "count"),))
    cfg = get_smoke_config("madeye-approx")

    print("building scene...")
    video = build_video(GRID, SceneConfig(fps=15, seed=13),
                        float(os.environ.get("REPRO_EX_DURATION", "8.0")))
    tables = detection_tables(video, workload)
    acc = workload_acc_table(video, workload, tables)

    params = distill_detector(cfg, video, tables, key)
    engine = InferenceEngine(cfg, params)

    ctrl = MadEyeController(GRID, workload, budget=BudgetConfig(fps=1.0))
    trace = NetworkTrace.fixed(24, 20, video.n_frames)
    visited = {}
    stride = video.fps  # 1 fps response rate

    print("serving (NN approximation model in the loop)...")
    t0 = time.time()
    for t in range(0, video.n_frames, stride):
        ctrl.report_network(trace.observed_mbps(t), trace.rtt_s)
        snap = video.snapshots[t]

        def observe(cells, zooms, _t=t, _snap=snap):
            if not cells:
                return []
            imgs = np.stack([
                render_image(_snap, GRID, int(c), (1.0, 2.0, 3.0)[int(z)],
                             res=RES)
                for c, z in zip(cells, zooms)])
            d = engine.score_batch(jnp.asarray(imgs))
            obs = []
            for i, (c, z) in enumerate(zip(cells, zooms)):
                keep = np.asarray(d.scores[i]) >= 0.3
                boxes = np.asarray(d.boxes[i])[keep]
                n = int(keep.sum())
                if n:
                    centers, sizes = boxes_to_scene(
                        boxes, GRID, int(c), (1.0, 2.0, 3.0)[int(z)])
                else:
                    centers = np.zeros((0, 2))
                    sizes = np.zeros((0, 2))
                obs.append(Observation(
                    counts={("yolov4", "person"): n},
                    areas={("yolov4", "person"):
                           float((boxes[:, 2] * boxes[:, 3]).sum())
                           if n else 0.0},
                    centroid=centers.mean(0) if n else np.zeros(2),
                    has_boxes=n > 0, box_centers=centers,
                    box_sizes=sizes))
            return obs

        res = ctrl.step(observe)
        zoom_of = {c: int(z) for c, z in zip(res.explored, res.zooms)}
        visited[t] = [(c, zoom_of[c]) for c in res.sent]

    accuracy = evaluate_selection(video, workload, tables, visited)
    n_steps = len(visited)
    print(f"  {n_steps} timesteps in {time.time()-t0:.1f}s "
          f"({(time.time()-t0)/n_steps*1e3:.0f} ms/step on CPU)")
    print(f"\nNN-in-the-loop MadEye accuracy: {accuracy:.3f}")
    T, N, Z = acc.shape
    best_fixed = float(acc.reshape(T, N * Z).mean(0).max())
    print(f"(oracle best-fixed accuracy on the same scene: {best_fixed:.3f};"
          " the gap is the 100-step smoke detector's ranking noise)")


if __name__ == "__main__":
    main()
