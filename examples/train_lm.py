"""Train a reduced LM end-to-end with the full substrate: AdamW, cosine
schedule, fault-tolerant checkpointing with restart, straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py

This is the CPU-scale version of launch/train.py's cluster loop: a few
hundred steps of the stablelm-family smoke config on synthetic token
streams; kill it mid-run and re-run to watch it resume from the last
atomic checkpoint.
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.train import synthetic_batch, train_loop

CKPT = "/tmp/repro_train_lm_ckpt"


def main():
    cfg = get_smoke_config("stablelm-3b")
    shape = ShapeSpec("example", "train", seq_len=64, global_batch=8)
    print(f"training {cfg.name} ({cfg.n_layers}L d{cfg.d_model}) "
          f"for 200 steps, ckpt -> {CKPT}")
    t0 = time.time()
    params, opt = train_loop(cfg, shape, steps=200, lr=3e-3,
                             ckpt_dir=CKPT, ckpt_every=50, log_every=25)
    print(f"done in {time.time()-t0:.0f}s")

    # quick eval: loss on a held-out batch must be below init loss
    from repro.models.transformer import lm_loss
    key = jax.random.PRNGKey(123)
    batch = synthetic_batch(cfg, shape, key)
    final = float(lm_loss(params, cfg, batch["tokens"], batch["labels"]))
    print(f"held-out loss {final:.3f} "
          f"(random-init baseline ~{np.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
