"""Continual distillation with orientation-balanced replay (paper §3.2).

    PYTHONPATH=src python examples/continual_distillation.py

Simulates the backend's continual-learning loop: the camera keeps
visiting a drifting hotspot, fresh teacher labels arrive only for visited
orientations, and the replay buffer pads neighbors (<=3 hops) so the
student doesn't catastrophically forget the rest of the grid. Compares
rank quality of balanced vs naive (fresh-only) retraining.

REPRO_EX_DURATION / REPRO_EX_EVALS shrink the scene and the rank-quality
evaluation (the CI smoke test runs this as a subprocess with tiny
overrides).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import DEFAULT_GRID, Query, Workload
from repro.core import continual
from repro.core.distill import spearman, teacher_labels
from repro.data import SceneConfig, build_video, render_image
from repro.models import detector as det
from repro.serving import detection_tables

GRID = DEFAULT_GRID
RES = 64
KEY = jax.random.PRNGKey(0)


def make_batch(video, tables, samples, cfg):
    imgs, bxs, cls, vld = [], [], [], []
    for (t, c) in samples:
        imgs.append(render_image(video.snapshots[t], GRID, c, 1.0, res=RES))
        d = tables[("yolov4", "person")].dets[1.0][t][c]
        tgt = teacher_labels([d["boxes"]], [np.zeros(len(d["boxes"]), int)],
                             cfg.max_boxes)
        bxs.append(tgt.boxes[0])
        cls.append(tgt.classes[0])
        vld.append(tgt.valid[0])
    return (jnp.asarray(np.stack(imgs)), jnp.asarray(np.stack(bxs)),
            jnp.asarray(np.stack(cls)), jnp.asarray(np.stack(vld)))


def rank_quality(params, cfg, video, tables, rng,
                 n_eval=int(os.environ.get("REPRO_EX_EVALS", "40"))):
    """Spearman correlation between NN counts and teacher counts across
    random orientation sets."""
    from repro.serving.engine import InferenceEngine
    engine = InferenceEngine(cfg, params)
    rhos = []
    for _ in range(n_eval):
        t = int(rng.integers(0, video.n_frames))
        cells = rng.choice(GRID.n_cells, 6, replace=False)
        true = np.array([tables[("yolov4", "person")].dets[1.0][t][int(c)]
                         ["count"] for c in cells], float)
        if true.max() == 0:
            continue
        imgs = np.stack([render_image(video.snapshots[t], GRID, int(c),
                                      1.0, res=RES) for c in cells])
        counts, _ = engine.counts_and_areas(jnp.asarray(imgs))
        rhos.append(spearman(np.asarray(counts, float), true))
    return float(np.mean(rhos))


def main():
    cfg = get_smoke_config("madeye-approx")
    workload = Workload((Query("yolov4", "person", "count"),))
    print("building scene...")
    video = build_video(GRID, SceneConfig(fps=15, seed=21),
                        float(os.environ.get("REPRO_EX_DURATION", "10.0")))
    tables = detection_tables(video, workload)
    rng = np.random.default_rng(0)

    # visit trace: the camera dwells hard on two cells (severe imbalance —
    # the paper's 9.3%-coverage regime)
    visit_trace = []
    for t in range(0, video.n_frames, 2):
        visit_trace.append((t, 12 if (t // 30) % 2 == 0 else 13))

    for mode in ("balanced", "naive"):
        params = det.detector_init(KEY, cfg)
        opt = continual.init_finetune(params)
        buffer = continual.ReplayBuffer(GRID.n_cells)
        # bootstrap history: the paper's initial fine-tuning set covers
        # every orientation — that is what balanced replay pads from
        for c0 in range(GRID.n_cells):
            for tb in (0, 5, 10):
                buffer.add(c0, (tb, c0))
        window_counts = np.zeros(GRID.n_cells, int)
        trained_cells = set()
        for (t, c) in visit_trace:
            buffer.add(c, (t, c))
            window_counts[c] += 1
            if t % 15 != 0:
                continue
            if mode == "balanced":
                samples = continual.sample_balanced(
                    buffer, window_counts, c, GRID, max_total=16)
            else:
                samples = buffer.recent(c, 16)
            if not samples:
                continue
            trained_cells.update(cc for (_, cc) in samples)
            batch = make_batch(video, tables, samples, cfg)
            for _ in range(3):
                params, opt, loss = continual.finetune_step(
                    params, opt, cfg, *batch, lr=3e-3)
            window_counts[:] = 0
        rho = rank_quality(params, cfg, video, tables,
                           np.random.default_rng(1))
        print(f"{mode:>9} replay: rank quality (Spearman) = {rho:+.3f}  "
              f"(trained on {len(trained_cells)}/{GRID.n_cells} "
              f"orientations)")


if __name__ == "__main__":
    main()
