"""Quickstart: MadEye vs the oracle baselines on a procedural scene.

    PYTHONPATH=src python examples/quickstart.py

Builds a 15-second scene, registers a 4-query workload (the paper's
{model, object, task} triples), runs the full MadEye loop at 5 fps over a
{24 Mbps, 20 ms} link, and prints workload accuracy against the oracle
fixed/dynamic baselines.

Set REPRO_EX_DURATION to shrink the scene (the CI smoke test runs every
example as a subprocess with a few-second override).
"""
import os
import time

from repro.core import DEFAULT_GRID, Query, Workload
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.serving import (
    NetworkTrace,
    detection_tables,
    run_madeye,
    run_scheme,
    workload_acc_table,
)


def main():
    workload = Workload((
        Query("yolov4", "person", "count"),
        Query("frcnn", "car", "detect"),
        Query("ssd", "person", "binary"),
        Query("tiny-yolov4", "person", "agg_count"),
    ))

    duration = float(os.environ.get("REPRO_EX_DURATION", "15.0"))
    print("building scene + teacher detection tables...")
    t0 = time.time()
    video = build_video(DEFAULT_GRID, SceneConfig(fps=15, seed=42), duration)
    tables = detection_tables(video, workload)
    acc = workload_acc_table(video, workload, tables)
    print(f"  done in {time.time()-t0:.1f}s "
          f"({video.n_frames} frames x {DEFAULT_GRID.n_cells} cells "
          f"x 3 zooms)")

    budget = BudgetConfig(fps=5.0)
    trace = NetworkTrace.fixed(24, 20, video.n_frames)

    res = run_madeye(video, workload, tables, budget, trace, acc_table=acc)
    print(f"\nMadEye        : accuracy {res.accuracy:.3f} "
          f"(shape {res.mean_shape:.1f} cells/step, "
          f"{res.frames_sent/len(res.visited):.1f} frames shipped/step, "
          f"best orientation explored {res.best_explored_rate*100:.0f}%)")

    for scheme in ("one_time_fixed", "best_fixed", "best_dynamic"):
        r = run_scheme(video, workload, tables, scheme, budget=budget,
                       acc_table=acc)
        marker = " <- oracle" if "dynamic" in scheme or "best" in scheme \
            else ""
        print(f"{scheme:14s}: accuracy {r.accuracy:.3f}{marker}")


if __name__ == "__main__":
    main()
