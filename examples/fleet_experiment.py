"""Fleet experiments through the unified API: one declarative spec.

    PYTHONPATH=src python examples/fleet_experiment.py

Describes a heterogeneous camera fleet as a `FleetRunSpec` — provider
name + kwargs, workload, budget, episode length, seed — and runs it with
`run_fleet`: ONE jit'd scan, per-camera scenes and network traces
generated on device, typed `FleetResult` out. The spec round-trips
through JSON, so experiment definitions can live in files or job queues;
swap provider="scene" for "detector" to put the approximation network in
the loop, or "tables" to replay the host-materialized parity substrate.

Set REPRO_EX_CAMERAS / REPRO_EX_STEPS to shrink the episode (the CI
smoke test runs every example as a subprocess with tiny overrides).
"""
import os

import numpy as np

from repro.fleet import FleetRunSpec, run_fleet


def main():
    f = int(os.environ.get("REPRO_EX_CAMERAS", "8"))
    steps = int(os.environ.get("REPRO_EX_STEPS", "24"))
    rng = np.random.default_rng(0)

    spec = FleetRunSpec(
        provider="scene", n_cameras=f, n_steps=steps, seed=0,
        budget={"fps": 3.0},
        provider_kwargs={
            "scene_seeds": np.arange(f),            # world per camera
            "person_speed": rng.uniform(0.8, 2.0, f),
            "n_people": rng.integers(4, 15, f),
            "mbps": np.full(f, 24.0), "net_seed": 0,  # mobile links
        })
    # specs are data: ship them through JSON and back before running
    spec = FleetRunSpec.from_json(spec.to_json())

    res = run_fleet(spec)
    print(f"providers available via the same entry: tables, scene, "
          f"detector (spec.provider={spec.provider!r})")
    print(f"fleet accuracy {res.accuracy:.3f} over {res.n_steps} steps "
          f"x {res.n_cameras} cameras "
          f"(mean shape {res.mean_shape:.1f}, "
          f"{sum(res.frames_sent)} frames shipped, "
          f"{res.camera_steps_per_s:.0f} camera-steps/s incl. compile)")
    print(f"result JSON: {len(res.to_json())} bytes "
          f"(per-step accuracies, chosen orientations, frames, timings)")


if __name__ == "__main__":
    main()
