"""Shared benchmark substrate: videos, workloads W1-W10, cached tables.

The paper evaluates 50 YouTube-derived videos x 10 workloads; offline we
use procedurally generated scenes (repro/data) with seeds as "videos".
Detection tables are built once per video for all 8 (model, object) pairs
and shared across workloads/figures — the same amortization the paper
gets from running every query on all orientations once (§2.2).
"""
from __future__ import annotations

import functools
import os

import numpy as np

from repro.core import DEFAULT_GRID, Query, Workload
from repro.data import SceneConfig, build_video
from repro.serving import detection_tables
from repro.serving.accuracy import query_acc_table

GRID = DEFAULT_GRID
ZOOMS = (1.0, 2.0, 3.0)

MODELS = ("ssd", "frcnn", "yolov4", "tiny-yolov4")
OBJECTS = ("person", "car")
ALL_PAIRS = tuple((m, o) for m in MODELS for o in OBJECTS)

# quick mode (default): 3 videos x 20 s; full: 5 videos x 40 s
QUICK = os.environ.get("BENCH_FULL", "") == ""
VIDEO_SEEDS = (3, 7, 11) if QUICK else (3, 7, 11, 19, 23)
DURATION_S = 20.0 if QUICK else 40.0


def _wl(*rows) -> Workload:
    return Workload(tuple(Query(m, o, t) for (m, o, t) in rows))


# Appendix A.1, verbatim (people->person; task names canonicalized).
WORKLOADS = {
    "W1": _wl(("ssd", "person", "agg_count"),
              ("frcnn", "car", "binary"),
              ("ssd", "person", "count"),
              ("yolov4", "person", "detect"),
              ("frcnn", "person", "detect")),
    "W2": _wl(("yolov4", "person", "agg_count"),
              ("tiny-yolov4", "person", "agg_count"),
              ("tiny-yolov4", "person", "detect"),
              ("yolov4", "person", "binary"),
              ("tiny-yolov4", "person", "agg_count"),
              ("frcnn", "person", "count"),
              ("frcnn", "person", "detect"),
              ("frcnn", "car", "count"),
              ("yolov4", "person", "agg_count"),
              ("yolov4", "person", "detect"),
              ("yolov4", "person", "count"),
              ("tiny-yolov4", "person", "agg_count"),
              ("yolov4", "car", "count"),
              ("yolov4", "car", "detect"),
              ("tiny-yolov4", "car", "count"),
              ("ssd", "person", "binary"),
              ("frcnn", "car", "count"),
              ("ssd", "car", "count")),
    "W3": _wl(("ssd", "car", "binary"),
              ("frcnn", "person", "agg_count"),
              ("frcnn", "person", "count"),
              ("tiny-yolov4", "person", "binary"),
              ("tiny-yolov4", "person", "binary"),
              ("tiny-yolov4", "person", "agg_count"),
              ("yolov4", "person", "count"),
              ("frcnn", "person", "agg_count"),
              ("ssd", "person", "binary"),
              ("frcnn", "car", "count"),
              ("ssd", "car", "count")),
    "W4": _wl(("tiny-yolov4", "car", "count"),
              ("frcnn", "car", "detect"),
              ("frcnn", "person", "agg_count")),
    "W5": _wl(("tiny-yolov4", "car", "count"),
              ("ssd", "car", "count"),
              ("frcnn", "person", "agg_count")),
    "W6": _wl(("tiny-yolov4", "person", "agg_count"),
              ("tiny-yolov4", "person", "binary"),
              ("ssd", "car", "count"),
              ("yolov4", "person", "agg_count"),
              ("tiny-yolov4", "person", "count"),
              ("frcnn", "car", "binary"),
              ("ssd", "person", "detect"),
              ("frcnn", "car", "detect"),
              ("frcnn", "person", "agg_count"),
              ("yolov4", "car", "count"),
              ("tiny-yolov4", "person", "agg_count"),
              ("frcnn", "person", "detect"),
              ("ssd", "person", "agg_count"),
              ("yolov4", "car", "detect")),
    "W7": _wl(("yolov4", "person", "binary"),
              ("ssd", "person", "detect"),
              ("tiny-yolov4", "car", "binary"),
              ("tiny-yolov4", "person", "detect"),
              ("ssd", "person", "binary"),
              ("ssd", "person", "agg_count"),
              ("tiny-yolov4", "person", "detect"),
              ("ssd", "car", "count"),
              ("ssd", "person", "count"),
              ("frcnn", "person", "count"),
              ("yolov4", "person", "count"),
              ("frcnn", "person", "binary"),
              ("tiny-yolov4", "person", "agg_count"),
              ("frcnn", "person", "agg_count"),
              ("frcnn", "car", "count"),
              ("yolov4", "car", "binary")),
    "W8": _wl(("frcnn", "car", "count"),
              ("tiny-yolov4", "person", "binary"),
              ("yolov4", "person", "agg_count"),
              ("yolov4", "car", "count"),
              ("tiny-yolov4", "person", "agg_count"),
              ("frcnn", "person", "agg_count"),
              ("yolov4", "person", "agg_count"),
              ("frcnn", "car", "count"),
              ("ssd", "car", "count"),
              ("frcnn", "car", "count"),
              ("ssd", "car", "binary"),
              ("yolov4", "car", "binary"),
              ("ssd", "car", "binary"),
              ("ssd", "person", "count"),
              ("yolov4", "person", "count"),
              ("yolov4", "car", "binary"),
              ("frcnn", "person", "agg_count"),
              ("ssd", "car", "detect")),
    "W9": _wl(("tiny-yolov4", "person", "agg_count"),
              ("frcnn", "person", "count"),
              ("frcnn", "person", "count"),
              ("tiny-yolov4", "car", "detect"),
              ("tiny-yolov4", "person", "binary"),
              ("yolov4", "person", "detect"),
              ("frcnn", "person", "count"),
              ("yolov4", "person", "agg_count"),
              ("ssd", "person", "agg_count")),
    "W10": _wl(("frcnn", "person", "agg_count"),
               ("frcnn", "car", "count"),
               ("frcnn", "person", "count")),
}

_ALL_PAIR_WL = Workload(tuple(
    Query(m, o, "count") for (m, o) in ALL_PAIRS))


@functools.lru_cache(maxsize=8)
def substrate(seed: int, duration_s: float = DURATION_S, fps: int = 15):
    """(video, tables-for-all-8-pairs) — cached per video seed."""
    video = build_video(GRID, SceneConfig(fps=fps, seed=seed), duration_s)
    tables = detection_tables(video, _ALL_PAIR_WL, ZOOMS)
    return video, tables


class AccCache:
    """Per-video cache of query/workload accuracy tables."""

    def __init__(self, video, tables):
        self.video = video
        self.tables = tables
        self._q = {}

    def query(self, model: str, obj: str, task: str) -> np.ndarray:
        key = (model, obj, task)
        if key not in self._q:
            self._q[key] = query_acc_table(
                self.video, self.tables[(model, obj)],
                task if task != "agg_count" else "count", ZOOMS)
        return self._q[key]

    def workload(self, wl: Workload) -> np.ndarray:
        acc = None
        for q in wl.queries:
            t = self.query(q.model, q.obj, q.task)
            acc = t if acc is None else acc + t
        return acc / len(wl.queries)


@functools.lru_cache(maxsize=8)
def acc_cache(seed: int, duration_s: float = DURATION_S) -> AccCache:
    video, tables = substrate(seed, duration_s)
    return AccCache(video, tables)


def git_sha() -> str:
    """Short commit sha of the repo this benchmark run measures —
    "unknown" outside a git checkout (extracted tarball, CI cache).
    Stamped into BENCH_history.jsonl so the perf trajectory maps back
    to commits."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.TimeoutExpired):
        return "unknown"


def median_iqr(values) -> tuple:
    v = np.asarray(sorted(values), float)
    return (float(np.median(v)), float(np.percentile(v, 25)),
            float(np.percentile(v, 75)))
