"""§5.4 deep dives: rotation speed, grid granularity, controller overhead."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import MadEyeController
from repro.core.grid import OrientationGrid
from repro.core.tradeoff import BudgetConfig
from repro.data import SceneConfig, build_video
from repro.serving import NetworkTrace, detection_tables, workload_acc_table
from repro.serving.pipeline import run_madeye


def run() -> dict:
    out = {}
    wl = common.WORKLOADS["W4"]

    print("\n== §5.4 rotation speed (15 fps, {24 Mbps, 20 ms}) ==")
    for speed in (200, 400, 500, 1e9):
        accs = []
        for seed in common.VIDEO_SEEDS:
            cache = common.acc_cache(seed)
            video, tables = cache.video, cache.tables
            acc = cache.workload(wl)
            trace = NetworkTrace.fixed(24, 20, video.n_frames)
            b = BudgetConfig(fps=15, rotation_speed=speed, pipelined=True)
            accs.append(run_madeye(video, wl, tables, b, trace,
                                   acc_table=acc).accuracy)
        m = float(np.median(accs))
        label = "inf" if speed > 1e6 else f"{speed:.0f}"
        print(f"  {label:>4} deg/s: median acc {m:.3f}")
        out[f"speed_{label}"] = m

    print("== §5.4 grid granularity (pan step sweep, 5 fps) ==")
    for pan_step in (15.0, 30.0, 45.0):
        grid = OrientationGrid(pan_step=pan_step)
        accs = []
        for seed in common.VIDEO_SEEDS[:2]:
            video = build_video(grid, SceneConfig(fps=15, seed=seed),
                                common.DURATION_S)
            tables = detection_tables(video, wl)
            acc = workload_acc_table(video, wl, tables)
            trace = NetworkTrace.fixed(24, 20, video.n_frames)
            b = BudgetConfig(fps=5, hop_degrees=pan_step)
            accs.append(run_madeye(video, wl, tables, b, trace,
                                   acc_table=acc).accuracy)
        m = float(np.median(accs))
        print(f"  pan step {pan_step:.0f}° ({grid.n_cells} cells): "
              f"median acc {m:.3f}")
        out[f"grid_{int(pan_step)}"] = m

    print("== §5.4 controller overhead ==")
    cache = common.acc_cache(common.VIDEO_SEEDS[0])
    ctrl = MadEyeController(common.GRID, wl, budget=BudgetConfig(fps=5))
    import numpy as _np

    def observe(cells, zooms):
        from repro.core.madeye import Observation
        return [Observation({(q.model, q.obj): 1 for q in wl.queries},
                            {(q.model, q.obj): 0.01 for q in wl.queries},
                            common.GRID.centers[c], True,
                            common.GRID.centers[c][None],
                            _np.ones((1, 2))) for c in cells]

    t0 = time.perf_counter()
    n = 200
    for _ in range(n):
        ctrl.step(observe)
    dt = (time.perf_counter() - t0) / n
    print(f"  controller step: {dt*1e6:.0f} us "
          "(paper: 17 us selection + inference; ours includes full "
          "bookkeeping in Python)")
    out["ctrl_us"] = dt * 1e6

    from repro.core.path import planner_for
    import numpy as np2
    planner = planner_for(common.GRID)
    mask = np2.zeros(common.GRID.n_cells, bool)
    mask[[6, 7, 8, 11, 12, 13]] = True
    t0 = time.perf_counter()
    for _ in range(2000):
        planner.subtree_walk(mask, 12)
    dt = (time.perf_counter() - t0) / 2000
    print(f"  path computation: {dt*1e6:.0f} us (paper: 14 us)")
    out["path_us"] = dt * 1e6
    return out


if __name__ == "__main__":
    run()
