"""Table 1: how many optimally-placed fixed cameras match MadEye-k?"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.tradeoff import BudgetConfig
from repro.serving import NetworkTrace
from repro.serving.pipeline import run_madeye_topk, run_scheme


def run(workload_names=("W1", "W7")) -> dict:
    fps, mbps, rtt = 5, 24, 20
    out = {}
    print("\n== Table 1: fixed-camera equivalence of MadEye-k ==")
    for k in (1, 2, 3):
        made, fixed_curves = [], []
        for seed in common.VIDEO_SEEDS:
            cache = common.acc_cache(seed)
            for w in workload_names:
                wl = common.WORKLOADS[w]
                video, tables = cache.video, cache.tables
                acc = cache.workload(wl)
                trace = NetworkTrace.fixed(mbps, rtt, video.n_frames)
                b = BudgetConfig(fps=fps)
                made.append(run_madeye_topk(
                    video, wl, tables, b, trace, k, acc_table=acc).accuracy)
                curve = [run_scheme(video, wl, tables, "best_fixed", k=kk,
                                    budget=b, acc_table=acc).accuracy
                         for kk in range(1, 9)]
                fixed_curves.append(curve)
        m_acc = float(np.median(made))
        curve = np.median(np.asarray(fixed_curves), axis=0)
        # linear interpolation: #fixed cameras needed to match m_acc
        n_fixed = 8.0
        for i in range(len(curve)):
            if curve[i] >= m_acc:
                if i == 0:
                    n_fixed = 1.0
                else:
                    lo, hi = curve[i - 1], curve[i]
                    n_fixed = i + (m_acc - lo) / max(hi - lo, 1e-9)
                break
        resource = n_fixed / k
        print(f"  MadEye-{k}: acc {m_acc:.3f} ~= {n_fixed:.1f} fixed "
              f"cameras -> {resource:.1f}x resource reduction")
        out[f"madeye{k}"] = {"acc": m_acc, "n_fixed": float(n_fixed),
                             "reduction": float(resource)}
    return out


if __name__ == "__main__":
    run()
