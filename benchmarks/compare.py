"""Benchmark regression gate: diff two BENCH json files by metric name.

CI runs the quick benchmark suite fresh and compares it against the
committed baseline (BENCH_repro.quick.json): any metric whose wall time
grew by more than --max-slowdown fails the job. Metrics present in only
one of the two files are *skipped*, not failed: a fresh-only metric
(`new`) is how a newly-landed benchmark looks before its baseline is
committed, and a baseline-only metric (`removed`) is how a renamed,
retired, or input-starved benchmark looks (run.py records no row when a
benchmark declines to run, e.g. bench_roofline without its dry-run
artifacts) before the baseline is regenerated — both are
reported so a PR reviewer sees the coverage change, neither can KeyError
or block the job.

Rows may also carry a "values" dict of named numeric results; `GATES`
holds absolute ceilings for those (e.g. the in-scan distillation
steady-state overhead must stay under 30% of the frozen detector leg) —
a value gate fails on the fresh measurement alone, no baseline needed.

  python -m benchmarks.compare BENCH_repro.quick.json fresh.json \
      --max-slowdown 2.0
"""
from __future__ import annotations

import argparse
import json
import sys

# absolute ceilings on named numeric results (row "values" dicts, see
# run.py's timed(values=...)) — these gate a metric's VALUE, not its
# wall time, so they fail even on a metric too new to have a baseline.
# fleet_distill_overhead_pct: in-scan continual distillation must stay
# under 30% steady-state overhead vs the frozen detector leg (the
# repro.learn design point: training reuses the inference forward's
# staged features, so learning adds head-conv work only).
GATES = {
    "fleet_distill_overhead_pct": 30.0,
}


def check_gates(fresh_values: dict, gates: dict | None = None) -> list:
    """Gate named numeric results against absolute ceilings. Returns
    failure strings; values absent from the fresh run are skipped (the
    leg didn't run), unknown values are ignored (no accidental gate)."""
    gates = GATES if gates is None else gates
    failures = []
    for name, vals in sorted(fresh_values.items()):
        for key, val in sorted((vals or {}).items()):
            if key not in gates:
                continue
            limit = gates[key]
            status = "FAIL" if val > limit else "ok"
            print(f"{status:4s} {name}.{key}: {val:.1f} "
                  f"(gate <= {limit:.1f})")
            if val > limit:
                failures.append(f"{name}.{key}: {val:.1f} exceeds "
                                f"gate {limit:.1f}")
    return failures


def compare(baseline: dict, fresh: dict, max_slowdown: float, *,
            base_derived: dict | None = None,
            fresh_derived: dict | None = None) -> list:
    """Returns a list of failure strings (empty = pass). Metrics present
    in only one input are reported as new/removed and never fail. The
    optional derived dicts ({name: headline-metric string}) add an
    informational drift line when a metric's derived value changed —
    never a failure, since derived values legitimately move with the
    code (that is the point of tracking them)."""
    base_derived = base_derived or {}
    fresh_derived = fresh_derived or {}
    failures = []
    for name, base_us in baseline.items():
        if name not in fresh:
            print(f"removed {name}: baseline {base_us:.0f}us has no "
                  f"fresh measurement (renamed or retired benchmark? "
                  f"regenerate the baseline to drop it)")
            continue
        ratio = fresh[name] / max(base_us, 1e-9)
        status = "FAIL" if ratio > max_slowdown else "ok"
        print(f"{status:4s} {name}: {base_us:.0f}us -> {fresh[name]:.0f}us "
              f"({ratio:.2f}x)")
        bd, fd = base_derived.get(name), fresh_derived.get(name)
        if bd is not None and fd is not None and bd != fd:
            print(f"     derived drift: {bd!r} -> {fd!r}")
        if ratio > max_slowdown:
            failures.append(f"{name}: {ratio:.2f}x slowdown "
                            f"(limit {max_slowdown:.2f}x)")
    for name in sorted(fresh.keys() - baseline.keys()):
        derived = fresh_derived.get(name)
        extra = f" [{derived}]" if derived else ""
        print(f"new  {name}: {fresh[name]:.0f}us{extra} "
              f"(no baseline yet)")
    return failures


def _load(path: str) -> tuple:
    """-> ({name: us_per_call}, {name: derived-metric string},
    {name: values dict})."""
    with open(path) as f:
        rows = json.load(f)
    return ({r["name"]: float(r["us_per_call"]) for r in rows},
            {r["name"]: r.get("derived") for r in rows},
            {r["name"]: r.get("values") for r in rows})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("fresh", help="freshly measured json")
    ap.add_argument("--max-slowdown", type=float, default=2.0,
                    help="fail when fresh/baseline exceeds this ratio")
    args = ap.parse_args(argv)
    base_us, base_d, _ = _load(args.baseline)
    fresh_us, fresh_d, fresh_v = _load(args.fresh)
    failures = compare(base_us, fresh_us, args.max_slowdown,
                       base_derived=base_d, fresh_derived=fresh_d)
    failures += check_gates(fresh_v)
    if failures:
        print("\nbench regression:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("\nno bench regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
