"""Figure 16 + §5.4 microbenchmarks: approximation-model rank quality.

Compares MadEye's detector-style approximation (counts from boxes) against
the count-CNN alternative (direct count regression — modeled as a noisier
count estimate, the failure mode the paper measured), reporting the median
rank assigned to the truly-best explored orientation.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving.teachers import approx_observation


def _best_rank(pred: np.ndarray, true: np.ndarray) -> int:
    """Rank (1-based) the prediction assigns to the truly-best item."""
    order = np.argsort(-pred, kind="stable")
    best = int(np.argmax(true))
    return int(np.where(order == best)[0][0]) + 1


def run(n_explored: int = 6) -> dict:
    rng = np.random.default_rng(0)
    det_ranks, cnt_ranks, agree = [], [], []
    for seed in common.VIDEO_SEEDS:
        video, tables = common.substrate(seed)
        key = ("yolov4", "person")
        T = video.n_frames
        for t in range(0, T, 3):
            cells = rng.choice(common.GRID.n_cells, n_explored,
                               replace=False)
            true = np.array([tables[key].dets[1.0][t][c]["count"]
                             for c in cells], float)
            if true.max() == 0:
                continue
            # detector-style approx: boxes -> counts (miss-degraded)
            det = np.array([approx_observation(
                tables[key].dets[1.0][t][c], miss_rate=0.12,
                seed_key=(t, c))["count"] for c in cells], float)
            # count-CNN: global regression — relative noise grows with
            # count (paper: "rank orderings extremely sensitive to small
            # errors in count prediction")
            noise = rng.normal(0, 0.75, n_explored)
            cnt = np.maximum(true + noise, 0)
            det_ranks.append(_best_rank(det, true))
            cnt_ranks.append(_best_rank(cnt, true))
            agree.append(_best_rank(det, true) == 1)

    out = {
        "detector_median_rank": float(np.median(det_ranks)),
        "count_cnn_median_rank": float(np.median(cnt_ranks)),
        "top1_agreement": float(np.mean(agree)),
    }
    print("\n== Fig 16: rank assigned to the best explored orientation ==")
    print(f"  MadEye detector approx: median rank "
          f"{out['detector_median_rank']:.1f} "
          f"(p75 {np.percentile(det_ranks, 75):.1f}; paper: 1.1-1.3)")
    print(f"  Count-CNN alternative : median rank "
          f"{out['count_cnn_median_rank']:.1f} "
          f"(p75 {np.percentile(cnt_ranks, 75):.1f})")
    print(f"  top-1 agreement {out['top1_agreement']*100:.0f}% "
          "(paper §5.4: explores best orientation 89.3%)")
    return out


if __name__ == "__main__":
    run()
