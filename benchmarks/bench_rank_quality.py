"""Figure 16 + §5.4 microbenchmarks: approximation-model rank quality.

Compares MadEye's detector-style approximation (counts from boxes) against
the count-CNN alternative (direct count regression — modeled as a noisier
count estimate, the failure mode the paper measured), reporting the median
rank assigned to the truly-best explored orientation.

`fleet_rank_quality` asks the same question of the *in-scan* pipelines:
on one scene, how well does the detector-backed provider's chosen
orientation rank among the explored set by oracle accuracy, vs the
oracle-backed (teacher-table) provider's choice? The detector leg runs
the candidate-sparse fused fast path — the shortlist is what makes an
episode-length comparison cheap enough to sit in the full sweep.

`fleet_learning_curve` adds the continual-distillation leg (repro.learn):
the same detector fleet with in-scan learning on, graded by how its
median chosen-rank moves from episode start to end (paper §3.4's claim:
the approximation model keeps up with the scene because it never stops
training), plus the steady-state overhead of learning vs the frozen leg
(compare.py gates it below 30%).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.serving.teachers import approx_observation


def _best_rank(pred: np.ndarray, true: np.ndarray) -> int:
    """Rank (1-based) the prediction assigns to the truly-best item."""
    order = np.argsort(-pred, kind="stable")
    best = int(np.argmax(true))
    return int(np.where(order == best)[0][0]) + 1


def _chosen_rank(acc: np.ndarray, out, step: int, cam: int = 0) -> int | None:
    """1-based rank (by oracle accuracy, among the explored cells at
    their chosen zooms) of the cell the controller picked at `step` —
    None when the step is degenerate (single cell or empty scene)."""
    explored = np.flatnonzero(np.asarray(out.explored)[step, cam])
    if explored.size < 2:
        return None
    zooms = np.asarray(out.zooms)[step, cam]
    vals = np.asarray([acc[step, c, zooms[c]] for c in explored])
    chosen = int(np.asarray(out.chosen)[step, cam])
    if vals.max() <= 0 or chosen not in explored:
        return None
    return 1 + int(np.sum(vals > vals[explored == chosen][0]))


def fleet_rank_quality(n_steps: int = 16, shortlist_k: int = 18) -> dict:
    """Detector-backed vs oracle-backed orientation choices on the same
    scene: median oracle-accuracy rank of each controller's chosen
    orientation (camera 0). The ranks are read straight off the in-scan
    FleetMetrics `chosen_rank` output (repro.obs) — no
    materialize_scene_tables replay pass; tests/test_obs.py pins the
    in-scan rank against the host `_chosen_rank` replay grading."""
    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig
    from repro.fleet import (
        fleet_config,
        fleet_statics,
        make_detector_provider,
        run_fleet_episode,
        workload_spec,
    )
    from repro.obs import MetricsSpec, median_valid_rank

    wl = _fleet_workload()
    cfg = fleet_config(DEFAULT_GRID, BudgetConfig(fps=3.0))
    spec = workload_spec(wl)
    statics = fleet_statics(DEFAULT_GRID)
    provider, st0 = make_detector_provider(
        DEFAULT_GRID, wl, cfg, n_cameras=1, n_steps=n_steps,
        scene_seeds=[3], shortlist_k=shortlist_k)
    mspec = MetricsSpec(ewma=False, budget=False, shortlist=False)
    _, _, m_det = run_fleet_episode(cfg, spec, statics, st0, provider,
                                    metrics=mspec)
    _, _, m_orc = run_fleet_episode(cfg, spec, statics, st0,
                                    provider.scene, metrics=mspec)
    det = np.asarray(m_det["chosen_rank"])
    return {
        "fleet_det_median_rank": median_valid_rank(det),
        "fleet_oracle_median_rank": median_valid_rank(
            m_orc["chosen_rank"]),
        "fleet_rank_steps": int((det > 0).sum()),
    }


def _fleet_workload():
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def _timed_run(spec, repeats: int = 2):
    """run_fleet the spec once, then re-run the compiled episode
    `repeats` times and keep the best steady-state time — single-episode
    wall times at this scale are noisy enough to blow a 30% gate."""
    import jax

    from repro.fleet import prepare_fleet_run, run_fleet_episode

    prep = prepare_fleet_run(spec)
    res = best = None
    for i in range(repeats + 1):
        t0 = time.perf_counter()
        res = jax.block_until_ready(prep.episode())
        dt = time.perf_counter() - t0
        if i > 0:                   # first call pays the jit compile
            best = dt if best is None else min(best, dt)
    return res, best


def _median_rank_split(chosen_rank: np.ndarray) -> tuple:
    """(first-third median, last-third median) of the valid ranks."""
    from repro.obs import median_valid_rank

    e = chosen_rank.shape[0]
    return (median_valid_rank(chosen_rank[:e // 3]),
            median_valid_rank(chosen_rank[-(e // 3):]))


def fleet_learning_curve(quick: bool = False) -> dict:
    """The in-scan continual-distillation learning curve (repro.learn,
    paper §3.4): the same detector fleet frozen vs distill-on, graded by
    the in-scan `chosen_rank` metric. Reports

      fleet_rank_start / fleet_rank_end   distill-on median chosen_rank
                                          over the first vs last third
                                          of the episode (the curve —
                                          end should approach 1.0)
      fleet_rank_frozen                   frozen-detector median rank
                                          (flat — the control)
      fleet_rank_end_k9                   end-rank at shortlist_k=9 (the
                                          rank-vs-K sweep row: fewer
                                          candidates = fewer training
                                          pairs per step)
      fleet_distill_overhead_pct          steady-state cost of learning
                                          over the frozen leg, best-of-
                                          repeats — compare.py gates
                                          this below 30%
    """
    from repro.fleet import FleetRunSpec

    steps = 32 if quick else 64
    base = dict(provider="detector", n_cameras=2, n_steps=steps,
                budget={"fps": 3.0}, metrics=True, seed=3,
                provider_kwargs={"scene_seeds": [3, 5]})
    res_off, t_off = _timed_run(FleetRunSpec(shortlist_k=18, **base))
    res_on, t_on = _timed_run(
        FleetRunSpec(shortlist_k=18, distill=True, **base))
    res_k9, _ = _timed_run(
        FleetRunSpec(shortlist_k=9, distill=True, **base), repeats=1)

    m_off, m_on = res_off[2], res_on[2]
    from repro.obs import median_valid_rank
    start, end = _median_rank_split(np.asarray(m_on["chosen_rank"]))
    _, end_k9 = _median_rank_split(np.asarray(res_k9[2]["chosen_rank"]))
    return {
        "fleet_rank_start": start,
        "fleet_rank_end": end,
        "fleet_rank_frozen": median_valid_rank(m_off["chosen_rank"]),
        "fleet_rank_end_k9": end_k9,
        "fleet_distill_overhead_pct": 100.0 * (t_on - t_off) / t_off,
        "fleet_curve_steps": steps,
    }


def run(n_explored: int = 6) -> dict:
    rng = np.random.default_rng(0)
    det_ranks, cnt_ranks, agree = [], [], []
    for seed in common.VIDEO_SEEDS:
        video, tables = common.substrate(seed)
        key = ("yolov4", "person")
        T = video.n_frames
        for t in range(0, T, 3):
            cells = rng.choice(common.GRID.n_cells, n_explored,
                               replace=False)
            true = np.array([tables[key].dets[1.0][t][c]["count"]
                             for c in cells], float)
            if true.max() == 0:
                continue
            # detector-style approx: boxes -> counts (miss-degraded)
            det = np.array([approx_observation(
                tables[key].dets[1.0][t][c], miss_rate=0.12,
                seed_key=(t, c))["count"] for c in cells], float)
            # count-CNN: global regression — relative noise grows with
            # count (paper: "rank orderings extremely sensitive to small
            # errors in count prediction")
            noise = rng.normal(0, 0.75, n_explored)
            cnt = np.maximum(true + noise, 0)
            det_ranks.append(_best_rank(det, true))
            cnt_ranks.append(_best_rank(cnt, true))
            agree.append(_best_rank(det, true) == 1)

    out = {
        "detector_median_rank": float(np.median(det_ranks)),
        "count_cnn_median_rank": float(np.median(cnt_ranks)),
        "top1_agreement": float(np.mean(agree)),
    }
    print("\n== Fig 16: rank assigned to the best explored orientation ==")
    print(f"  MadEye detector approx: median rank "
          f"{out['detector_median_rank']:.1f} "
          f"(p75 {np.percentile(det_ranks, 75):.1f}; paper: 1.1-1.3)")
    print(f"  Count-CNN alternative : median rank "
          f"{out['count_cnn_median_rank']:.1f} "
          f"(p75 {np.percentile(cnt_ranks, 75):.1f})")
    print(f"  top-1 agreement {out['top1_agreement']*100:.0f}% "
          "(paper §5.4: explores best orientation 89.3%)")

    out.update(fleet_rank_quality())
    print("== In-scan pipelines: rank of the CHOSEN orientation ==")
    print(f"  detector-backed (shortlist fast path): median rank "
          f"{out['fleet_det_median_rank']:.1f}")
    print(f"  oracle-backed   (teacher tables)     : median rank "
          f"{out['fleet_oracle_median_rank']:.1f} "
          f"({out['fleet_rank_steps']} graded steps)")
    return out


if __name__ == "__main__":
    run()
