"""Figures 3/7/9/10/11: best-orientation dynamics statistics.

Validates that the procedural scenes reproduce the regime the paper
measured on real 360° videos: rapid temporal switching (Fig 3), short
per-orientation best-durations (Fig 7), spatially local transitions
(Fig 9), clustered top-k (Fig 10), and correlated neighbors (Fig 11).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(workload_names=("W1", "W6")) -> dict:
    switch_gaps, dwell_totals, hop_dists, topk_spans = [], [], [], []
    corr1, corr2 = [], []
    fps = 15

    for seed in common.VIDEO_SEEDS:
        cache = common.acc_cache(seed)
        for wname in workload_names:
            acc = cache.workload(common.WORKLOADS[wname]).max(-1)  # [T, N]
            T, N = acc.shape
            best = acc.argmax(-1)

            # Fig 3: time between switches
            last = 0
            for t in range(1, T):
                if best[t] != best[t - 1]:
                    switch_gaps.append((t - last) / fps)
                    last = t

            # Fig 7: total best-time per orientation
            for c in range(N):
                total = float((best == c).sum()) / fps
                if total > 0:
                    dwell_totals.append(total)

            # Fig 9: spatial distance between successive bests
            for t in range(1, T):
                if best[t] != best[t - 1]:
                    hop_dists.append(
                        common.GRID.angular_distance[best[t - 1], best[t]])

            # Fig 10: max pairwise distance among top-k
            for t in range(0, T, 5):
                for k in (2, 6):
                    top = np.argsort(-acc[t])[:k]
                    span = max(common.GRID.hop_distance[a, b]
                               for a in top for b in top)
                    topk_spans.append((k, span))

            # Fig 11: neighbor correlation of accuracy deltas
            deltas = np.diff(acc, axis=0)          # [T-1, N]
            for i in range(N):
                for j in range(i + 1, N):
                    h = common.GRID.hop_distance[i, j]
                    if h > 2:
                        continue
                    if deltas[:, i].std() < 1e-9 or deltas[:, j].std() < 1e-9:
                        continue
                    r = float(np.corrcoef(deltas[:, i], deltas[:, j])[0, 1])
                    (corr1 if h == 1 else corr2).append(r)

    out = {}
    print("\n== Fig 3: time between best-orientation switches ==")
    frac_1s = float(np.mean(np.asarray(switch_gaps) <= 1.0))
    print(f"  switches <= 1 s since last: {frac_1s*100:.0f}% (paper: 85%)")
    out["frac_switch_1s"] = frac_1s

    print("== Fig 7: total best-time per orientation ==")
    m, lo, hi = common.median_iqr(dwell_totals)
    print(f"  median total best-time {m:.1f} s (paper: 5-6 s per 10-min; "
          f"ours per {common.DURATION_S:.0f}-s video)")
    out["median_dwell_s"] = m

    print("== Fig 9: spatial distance of successive bests ==")
    print(f"  median {np.median(hop_dists):.0f}°, p90 "
          f"{np.percentile(hop_dists, 90):.0f}° (paper: 30°, 63.5°)")
    out["median_hop_deg"] = float(np.median(hop_dists))

    print("== Fig 10: top-k spatial clustering ==")
    for k in (2, 6):
        spans = [s for (kk, s) in topk_spans if kk == k]
        print(f"  k={k}: p75 span {np.percentile(spans, 75):.0f} hops "
              f"(paper: {1 if k == 2 else 2})")

    print("== Fig 11: neighbor accuracy-delta correlation ==")
    c1 = float(np.mean(corr1)) if corr1 else 0.0
    c2 = float(np.mean(corr2)) if corr2 else 0.0
    print(f"  1-hop {c1:.2f} (paper 0.83), 2-hop {c2:.2f} (paper 0.75)")
    out["corr_1hop"], out["corr_2hop"] = c1, c2
    return out


if __name__ == "__main__":
    run()
