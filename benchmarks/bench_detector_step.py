"""In-step approximation model: render+infer cost per camera-step.

The DetectorProvider closes the paper's camera-side loop — candidate
(cell, zoom) crops are rasterized from the device scene and scored by
the distilled detector network *inside* the jit'd episode scan. This
benchmark runs four pipelines on identical worlds at each fleet size:

  oracle   the teacher-table scene episode (no in-scan render+infer) —
           the cost floor everything is measured against
  legacy   the pre-shortlist reference: every N*Z window rendered to
           pixels, scored through a serial per-chunk lax.map
  fast     the fused exhaustive path: same N*Z windows, but crops go
           straight to patch-embedding tokens (kernels/crop_patchify)
           and ONE batched forward over the flattened [F*K] axis
  short    the candidate-sparse path: the search-coupled shortlist
           keeps <= 25% of the windows before the fused forward

and reports steady-state camera-steps/sec per leg, each leg's overhead
factor over the oracle, and the two headline ratios: batching+fusion
alone (legacy/fast at K = N*Z) and the full fast path (legacy/short).
A fifth measurement reruns `fast` with the full in-scan FleetMetrics
(repro.obs) enabled and reports metrics_overhead_F — the telemetry tax
on the steady-state scan (gated < 1.15x by tests/test_obs.py).

  PYTHONPATH=src python -m benchmarks.bench_detector_step
"""
from __future__ import annotations

import os
import time

import numpy as np

FLEET_SIZES = (64, 256)
N_STEPS = 4
FPS = 3.0
SEED = 3
SHORT_FRAC = 0.25


def _workload():
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def run(fleet_sizes=FLEET_SIZES, n_steps: int = N_STEPS,
        quick: bool | None = None) -> dict:
    import dataclasses

    import jax

    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    if quick is None:
        quick = os.environ.get("BENCH_QUICK", "") == "1"
    if quick:
        fleet_sizes, n_steps = (8,), 3

    grid = DEFAULT_GRID
    wl = _workload()
    budget = BudgetConfig(fps=FPS)

    out = {"steps": n_steps, "fleets": list(fleet_sizes)}
    for f in fleet_sizes:
        base = dict(
            n_cameras=f, n_steps=n_steps, seed=SEED,
            grid=grid, workload=wl, budget=budget,
            scene_seeds=np.arange(f),
            person_speed=np.linspace(0.8, 2.0, f),
            n_people=np.linspace(4, 14, f).astype(int))
        prep = prepare_fleet_run(FleetRunSpec.from_objects(
            "detector", **base))
        c = prep.provider.scene.windows.shape[0]
        z = len(prep.cfg.zoom_levels)
        k_short = max(z, int(c * SHORT_FRAC) // z * z)
        out["windows"] = c
        out["shortlist_k"] = k_short

        legs = {}
        # every leg reuses the ONE built provider's scene — the
        # identical world: `short`/`legacy` are static-field variants
        # (shortlist_k / fused are treedef metadata, no rebuild),
        # `oracle` is the scene minus the in-scan render+infer
        for name, provider in (
                ("fast", prep.provider),
                ("short", dataclasses.replace(prep.provider,
                                              shortlist_k=k_short)),
                ("legacy", dataclasses.replace(prep.provider,
                                               fused=False)),
                ("oracle", prep.provider.scene)):
            t0 = time.perf_counter()
            jax.block_until_ready(prep.episode(provider=provider))
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            _, o = jax.block_until_ready(prep.episode(provider=provider))
            scan_s = time.perf_counter() - t0
            legs[name] = (compile_s, scan_s, o)

        # in-scan telemetry overhead: the same fast provider with the
        # full FleetMetrics enabled (repro.obs) — the acceptance gate is
        # metrics_overhead < 1.15x of the metrics-free scan
        from repro.obs import MetricsSpec

        mspec = MetricsSpec()
        jax.block_until_ready(prep.episode(metrics=mspec))
        t0 = time.perf_counter()
        jax.block_until_ready(prep.episode(metrics=mspec))
        metrics_scan = time.perf_counter() - t0

        cps = f * n_steps
        oracle_scan = legs["oracle"][1]
        for name in ("fast", "short", "legacy"):
            scan = legs[name][1]
            out[f"det_{name}_cps_{f}"] = float(cps / scan)
            out[f"det_{name}_overhead_{f}"] = float(scan / oracle_scan)
        out[f"oracle_cps_{f}"] = float(cps / oracle_scan)
        # headline metrics: the default provider config (fused
        # exhaustive) keeps the historical det_cps/det_overhead names
        out[f"det_cps_{f}"] = out[f"det_fast_cps_{f}"]
        out[f"det_overhead_{f}"] = out[f"det_fast_overhead_{f}"]
        out[f"batch_fusion_speedup_{f}"] = float(
            legs["legacy"][1] / legs["fast"][1])
        out[f"shortlist_speedup_{f}"] = float(
            legs["legacy"][1] / legs["short"][1])
        out[f"render_infer_us_per_camera_step_{f}"] = float(
            max(legs["fast"][1] - oracle_scan, 0.0) / cps * 1e6)
        out[f"det_compile_s_{f}"] = float(legs["fast"][0])
        out[f"metrics_cps_{f}"] = float(cps / metrics_scan)
        out[f"metrics_overhead_{f}"] = float(
            metrics_scan / legs["fast"][1])
        out[f"mean_shape_{f}"] = float(
            np.asarray(legs["fast"][2].n_explored, float).mean())
    return out


if __name__ == "__main__":
    res = run()
    for k, v in res.items():
        print(f"{k:36s} {v:.2f}" if isinstance(v, float) else
              f"{k:36s} {v}")
