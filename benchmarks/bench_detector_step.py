"""In-step approximation model: render+infer cost per camera-step.

The DetectorProvider closes the paper's camera-side loop — every
candidate (cell, zoom) crop is rasterized from the device scene and
scored by the distilled detector network *inside* the jit'd episode scan
(scene_jax.render + models/detector via serving.engine). That buys
fidelity (the ranking sees actual pixels, §3.4) at the price of N*Z
renders + forward passes per camera-step. This benchmark runs the
detector-backed and the oracle (teacher-table rasterizer) scene episodes
on identical worlds at each fleet size and reports steady-state
camera-steps/sec for both, the detector path's overhead factor, and the
marginal render+infer cost per camera-step.

  PYTHONPATH=src python -m benchmarks.bench_detector_step
"""
from __future__ import annotations

import os
import time

import numpy as np

FLEET_SIZES = (64, 256)
N_STEPS = 4
FPS = 3.0
SEED = 3


def _workload():
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def run(fleet_sizes=FLEET_SIZES, n_steps: int = N_STEPS,
        quick: bool | None = None) -> dict:
    import jax

    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    if quick is None:
        quick = os.environ.get("BENCH_QUICK", "") == "1"
    if quick:
        fleet_sizes, n_steps = (8,), 3

    grid = DEFAULT_GRID
    wl = _workload()
    budget = BudgetConfig(fps=FPS)

    out = {"steps": n_steps, "fleets": list(fleet_sizes)}
    for f in fleet_sizes:
        prep = prepare_fleet_run(FleetRunSpec.from_objects(
            "detector", n_cameras=f, n_steps=n_steps, seed=SEED,
            grid=grid, workload=wl, budget=budget,
            scene_seeds=np.arange(f),
            person_speed=np.linspace(0.8, 2.0, f),
            n_people=np.linspace(4, 14, f).astype(int)))
        legs = {}
        # the oracle leg reuses the detector provider's own scene — the
        # identical world, minus the in-scan render+infer
        for name, provider in (("det", prep.provider),
                               ("oracle", prep.provider.scene)):
            t0 = time.perf_counter()
            jax.block_until_ready(prep.episode(provider=provider))
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            _, o = jax.block_until_ready(prep.episode(provider=provider))
            scan_s = time.perf_counter() - t0
            legs[name] = (compile_s, scan_s, o)

        cps = f * n_steps
        det_scan, oracle_scan = legs["det"][1], legs["oracle"][1]
        out[f"det_cps_{f}"] = float(cps / det_scan)
        out[f"oracle_cps_{f}"] = float(cps / oracle_scan)
        out[f"det_overhead_{f}"] = float(det_scan / oracle_scan)
        out[f"render_infer_us_per_camera_step_{f}"] = float(
            max(det_scan - oracle_scan, 0.0) / cps * 1e6)
        out[f"det_compile_s_{f}"] = float(legs["det"][0])
        out[f"mean_shape_{f}"] = float(
            np.asarray(legs["det"][2].n_explored, float).mean())
    return out


if __name__ == "__main__":
    res = run()
    for k, v in res.items():
        print(f"{k:36s} {v:.2f}" if isinstance(v, float) else
              f"{k:36s} {v}")
