"""Figures 1 & 2: accuracy gains from adapting orientations.

Compares one-time-fixed / best-fixed / best-dynamic on every
(video, workload) pair, then breaks the best-dynamic-over-best-fixed win
down by task (Fig 2's "wins grow with query specificity").
"""
from __future__ import annotations


from benchmarks import common
from repro.core import Query, Workload
from repro.core.baselines import best_dynamic, best_fixed, one_time_fixed
from repro.serving.accuracy import evaluate_selection
from repro.serving.pipeline import ZOOM_LEVELS


def _oracle_accs(cache: common.AccCache, wl) -> dict:
    video, tables = cache.video, cache.tables
    acc = cache.workload(wl)
    T, N, Z = acc.shape
    flat = acc.reshape(T, N * Z)
    out = {}
    for name, chooser in (("one_time_fixed", one_time_fixed),
                          ("best_fixed", best_fixed),
                          ("best_dynamic", best_dynamic)):
        ch = chooser(flat)
        visited = {t: [(int(c) // Z, int(c) % Z)] for t, c in enumerate(ch)}
        out[name] = evaluate_selection(video, wl, tables, visited,
                                       ZOOM_LEVELS)
    return out


def run(workload_names=("W1", "W4", "W6", "W7", "W10")) -> dict:
    rows = {s: [] for s in ("one_time_fixed", "best_fixed", "best_dynamic")}
    for seed in common.VIDEO_SEEDS:
        cache = common.acc_cache(seed)
        for name in workload_names:
            accs = _oracle_accs(cache, common.WORKLOADS[name])
            for s, v in accs.items():
                rows[s].append(v)

    print("\n== Fig 1: degrees of orientation adaptation ==")
    med = {}
    for s, vals in rows.items():
        m, lo, hi = common.median_iqr(vals)
        med[s] = m
        print(f"  {s:>15}: median {m:.3f}  (IQR {lo:.3f}-{hi:.3f})")
    dyn_win = med["best_dynamic"] - med["best_fixed"]
    otf_win = med["best_dynamic"] - med["one_time_fixed"]
    print(f"  best_dynamic - best_fixed     = +{dyn_win*100:.1f}% "
          "(paper: 21.3-35.3%)")
    print(f"  best_dynamic - one_time_fixed = +{otf_win*100:.1f}% "
          "(paper: 30.4-46.3%)")

    # Fig 2: win breakdown by task (single-query workloads)
    print("\n== Fig 2: adaptation win by task specificity ==")
    task_wins = {}
    for task in ("binary", "count", "detect", "agg_count"):
        wins = []
        for seed in common.VIDEO_SEEDS:
            cache = common.acc_cache(seed)
            for model, obj in (("yolov4", "person"), ("yolov4", "car")):
                if task == "agg_count" and obj == "car":
                    continue    # paper excludes (tracker limitation)
                wl = Workload((Query(model, obj, task),))
                accs = _oracle_accs(cache, wl)
                wins.append(accs["best_dynamic"] - accs["best_fixed"])
        m, lo, hi = common.median_iqr(wins)
        task_wins[task] = m
        print(f"  {task:>10}: median win +{m*100:.1f}% (IQR {lo*100:.1f}"
              f"-{hi*100:.1f}%)")
    return {"fig1": med, "fig2": task_wins,
            "dyn_over_fixed": dyn_win}


if __name__ == "__main__":
    run()
