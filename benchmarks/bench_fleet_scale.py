"""Fleet-scale controller throughput: jit'd scan vs the numpy loop.

The ROADMAP north star is serving fleets, and the controller was the last
per-camera Python loop in the system. This benchmark steps a >=256-camera
fleet for >=64 controller timesteps in ONE jit'd lax.scan
(repro/fleet/runner.py) and compares camera-steps/sec against the numpy
`MadEyeController` driven exactly the way serving/pipeline.run_madeye
drives it, on the same scene config (seed 3, 4-query workload, 3 fps
response rate). Acceptance: >= 50x.

  PYTHONPATH=src python -m benchmarks.bench_fleet_scale
"""
from __future__ import annotations

import os
import time

import numpy as np

N_STEPS = 64
N_CAMERAS = 512 if os.environ.get("BENCH_FULL", "") == "" else 1024
FPS = 3.0
SEED = 3
MISS = 0.12


def _workload():
    # the serve launcher's default 4-query workload — one definition, so
    # the benchmarked controller matches what `serve --fleet` runs
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def run(n_cameras: int = N_CAMERAS, n_steps: int = N_STEPS) -> dict:
    import jax

    from repro.core import DEFAULT_GRID
    from repro.core.madeye import MadEyeController
    from repro.core.tradeoff import BudgetConfig
    from repro.data import SceneConfig, build_video
    from repro.fleet import FleetRunSpec, prepare_fleet_run
    from repro.serving import NetworkTrace, detection_tables
    from repro.serving.accuracy import workload_acc_table
    from repro.serving.pipeline import _observation_from_tables

    grid = DEFAULT_GRID
    wl = _workload()
    budget = BudgetConfig(fps=FPS)
    stride = max(1, int(round(15 / FPS)))
    duration = (n_steps * stride + 2) / 15.0
    video = build_video(grid, SceneConfig(fps=15, seed=SEED), duration)
    tables = detection_tables(video, wl)
    acc = workload_acc_table(video, wl, tables)
    trace = NetworkTrace.fixed(24.0, 20.0, video.n_frames)

    # -- numpy reference: one camera, one Python call per timestep,
    #    observations generated per step (how run_madeye drives it)
    frames = list(range(0, video.n_frames, stride))[:n_steps]
    ctrl = MadEyeController(grid, wl, budget=budget)
    t0 = time.perf_counter()
    for t in frames:
        ctrl.report_network(trace.observed_mbps(t), trace.rtt_s)

        def observe(cells, zooms, _t=t):
            return [_observation_from_tables(tables, wl, grid, _t, c,
                                             int(zi), MISS)
                    for c, zi in zip(cells, zooms)]

        ctrl.step(observe)
    numpy_cps = len(frames) / (time.perf_counter() - t0)

    # -- fleet: one declarative spec through the unified API (the tables
    #    provider materializes the episode once, then ONE jit'd scan
    #    steps all cameras); prepare/episode split so compile and
    #    steady-state are timed separately
    spec = FleetRunSpec.from_objects(
        "tables", n_cameras=n_cameras, n_steps=n_steps, seed=SEED,
        grid=grid, workload=wl, budget=budget,
        video=video, tables=tables, trace=trace, acc_table=acc,
        approx_miss=MISS)
    prep = prepare_fleet_run(spec)
    table_build_s = prep.build_s

    t0 = time.perf_counter()
    jax.block_until_ready(prep.episode())
    compile_s = time.perf_counter() - t0
    best = np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        _, out = jax.block_until_ready(prep.episode())
        best = min(best, time.perf_counter() - t0)
    fleet_cps = n_cameras * prep.provider.n_steps / best

    return {
        "cameras": n_cameras,
        "steps": int(prep.provider.n_steps),
        "numpy_cps": float(numpy_cps),
        "fleet_cps": float(fleet_cps),
        "speedup": float(fleet_cps / numpy_cps),
        "fleet_wall_s": float(best),
        "compile_s": float(compile_s),
        "table_build_s": float(table_build_s),
        "mean_shape": float(np.asarray(out.n_explored, float).mean()),
    }


if __name__ == "__main__":
    out = run()
    for k, v in out.items():
        print(f"{k:14s} {v:.2f}" if isinstance(v, float) else
              f"{k:14s} {v}")
