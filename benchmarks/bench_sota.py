"""Figure 15: MadEye vs Panoptes / PTZ tracking / UCB1 multi-armed bandit,
plus Table 2 (compatibility with Chameleon-style knob tuning)."""
from __future__ import annotations


from benchmarks import common
from repro.core.tradeoff import BudgetConfig
from repro.serving import NetworkTrace
from repro.serving.pipeline import run_madeye, run_scheme


def run(workload_names=("W1", "W6", "W9")) -> dict:
    fps, mbps, rtt = 15, 24, 20
    accs = {s: [] for s in ("madeye", "panoptes", "tracking", "ucb1")}
    for seed in common.VIDEO_SEEDS:
        cache = common.acc_cache(seed)
        for w in workload_names:
            wl = common.WORKLOADS[w]
            video, tables = cache.video, cache.tables
            acc = cache.workload(wl)
            trace = NetworkTrace.fixed(mbps, rtt, video.n_frames)
            b = BudgetConfig(fps=fps)
            accs["madeye"].append(
                run_madeye(video, wl, tables, b, trace,
                           acc_table=acc).accuracy)
            for s in ("panoptes", "tracking", "ucb1"):
                accs[s].append(
                    run_scheme(video, wl, tables, s, budget=b,
                               acc_table=acc).accuracy)

    print("\n== Fig 15: MadEye vs PTZ SOTA (15 fps, {24 Mbps, 20 ms}) ==")
    med = {}
    for s, vals in accs.items():
        m, lo, hi = common.median_iqr(vals)
        med[s] = m
        print(f"  {s:>9}: median {m:.3f} (IQR {lo:.3f}-{hi:.3f})")
    for s in ("panoptes", "tracking", "ucb1"):
        print(f"  MadEye vs {s}: +{(med['madeye']-med[s])*100:.1f}% "
              f"({med['madeye']/max(med[s],1e-9):.1f}x)")

    # Table 2: Chameleon compatibility — knob tuning lowers the frame rate
    # (resource reduction) without tanking accuracy; MadEye stacks on top.
    print("\n== Table 2: + Chameleon-style knob tuning ==")
    cham_fps = 5                  # 15 -> 5 fps = 3x fewer frames shipped
    rows = {"chameleon_fixed": [], "chameleon_madeye": []}
    for seed in common.VIDEO_SEEDS:
        cache = common.acc_cache(seed)
        for w in workload_names:
            wl = common.WORKLOADS[w]
            video, tables = cache.video, cache.tables
            acc = cache.workload(wl)
            trace = NetworkTrace.fixed(mbps, rtt, video.n_frames)
            b = BudgetConfig(fps=cham_fps)
            rows["chameleon_fixed"].append(
                run_scheme(video, wl, tables, "best_fixed", budget=b,
                           acc_table=acc).accuracy)
            rows["chameleon_madeye"].append(
                run_madeye(video, wl, tables, b, trace,
                           acc_table=acc).accuracy)
    cf, _, _ = common.median_iqr(rows["chameleon_fixed"])
    cm, _, _ = common.median_iqr(rows["chameleon_madeye"])
    print(f"  Chameleon (fixed orientation) : 3.0x fewer frames, "
          f"acc {cf:.3f}")
    print(f"  Chameleon + MadEye            : 3.0x fewer frames, "
          f"acc {cm:.3f} (+{(cm-cf)*100:.1f}%)")
    med["chameleon_gain"] = cm - cf
    return med


if __name__ == "__main__":
    run()
