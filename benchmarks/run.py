"""Benchmark driver — one entry per paper table/figure.

Prints a `name,us_per_call,derived` CSV row per benchmark (us_per_call =
wall time of the benchmark harness; derived = its headline metric) and
writes the same rows to BENCH_repro.json so the perf trajectory is
machine-readable across PRs.

Observability side channels of every run (repro.obs):
  BENCH_trace.json       Chrome trace of the whole sweep — one span per
                         benchmark leg plus the fleet build/compile/
                         steady and kernel-op spans underneath (open in
                         chrome://tracing or Perfetto; BENCH_TRACE
                         overrides the path)
  BENCH_telemetry.jsonl  the telemetry_stream benchmark's JSONL event
                         stream (BENCH_TELEMETRY overrides)
  BENCH_history.jsonl    append-only run log: {git_sha, date, quick,
                         metrics} per invocation — the perf trajectory
                         across commits (BENCH_HISTORY overrides)

  PYTHONPATH=src python -m benchmarks.run            # quick substrate
  BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
  BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run   # CI smoke:
      imports every benchmark module and runs a tiny subset (written to
      BENCH_repro.quick.json so the committed full-sweep trajectory in
      BENCH_repro.json is never clobbered by a smoke run)
"""
from __future__ import annotations

import json
import os
import time


def telemetry_stream(quick: bool) -> dict:
    """Run a metrics-enabled detector fleet and stream it as JSONL
    telemetry — benchmarks the full observability path (in-scan
    FleetMetrics -> chunked device->host transfer -> event schema) and
    leaves BENCH_telemetry.jsonl behind as a CI artifact."""
    from repro.fleet.api import FleetRunSpec, run_fleet
    from repro.obs import episode_events, median_valid_rank, write_events

    # fps=3 gives the searcher time to explore >1 cell per step, so the
    # chosen_rank metric has gradable (non-degenerate) steps to median
    spec = FleetRunSpec(
        provider="detector", n_cameras=4, n_steps=12 if quick else 32,
        shortlist_k=18, budget={"fps": 3.0}, metrics=True)
    r = run_fleet(spec)
    path = os.environ.get("BENCH_TELEMETRY", "BENCH_telemetry.jsonl")
    open(path, "w").close()          # this run's stream only, not a log
    n_events = write_events(episode_events(r, chunk=8), path)
    return {
        "events": n_events,
        "median_rank": median_valid_rank(r.metrics["chosen_rank"]),
        "steady_s": r.timings["steady_s"],
    }


def append_history(rows: list, quick: bool) -> str:
    """Append this run's summary to the BENCH_history.jsonl perf log."""
    from benchmarks import common

    path = os.environ.get("BENCH_HISTORY", "BENCH_history.jsonl")
    entry = {
        "git_sha": common.git_sha(),
        "date": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": quick,
        "metrics": {r["name"]: {"us_per_call": round(r["us_per_call"]),
                                "derived": r["derived"]} for r in rows},
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return path


def main() -> None:
    from benchmarks import (
        bench_deepdive,
        bench_detector_step,
        bench_e2e_sweeps,
        bench_fixed_cameras,
        bench_fleet_scale,
        bench_orientation_gains,
        bench_rank_quality,
        bench_roofline,
        bench_scene_device,
        bench_scene_stats,
        bench_sota,
    )

    from repro.obs import span, tracing

    quick = os.environ.get("BENCH_QUICK", "") == "1"
    rows = []

    def timed(name, fn, derive, values=None):
        t0 = time.perf_counter()
        with span(f"bench/{name}"):
            out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        if out is None:
            # the benchmark declined to run (missing input artifacts,
            # e.g. bench_roofline without a dry-run RESULTS file):
            # record nothing rather than a meaningless row — compare.py
            # reports the absent metric as removed without failing
            print(f"[skipped] {name}: no measurement recorded")
            return None
        row = {"name": name, "us_per_call": dt, "derived": derive(out)}
        if values is not None:
            # named numeric results compare.py can gate by absolute
            # limit (see compare.GATES), independent of wall time
            row["values"] = {k: float(out[k]) for k in values}
        rows.append(row)
        return out

    def run_all():
        if quick:
            # CI smoke: every module above is imported (so benchmark
            # imports can't silently rot) but only the cheap device-path
            # entries run
            timed("scene_device_vs_host_tables",
                  lambda: bench_scene_device.run(quick=True),
                  lambda o: f"hetero_speedup={o['hetero_speedup']:.0f}x"
                            f"@{o['cameras']}x{o['steps']}")
            timed("detector_in_step",
                  lambda: bench_detector_step.run(quick=True),
                  lambda o: f"det_cps={o['det_cps_8']:.0f} "
                            f"short_cps={o['det_short_cps_8']:.0f} "
                            f"mx={o['metrics_overhead_8']:.2f}x"
                            f"@8x{o['steps']}")
            timed("telemetry_stream",
                  lambda: telemetry_stream(quick=True),
                  lambda o: f"events={o['events']} "
                            f"median_rank={o['median_rank']:.1f}")
            timed("fleet_learning_curve",
                  lambda: bench_rank_quality.fleet_learning_curve(
                      quick=True),
                  lambda o: f"rank={o['fleet_rank_start']:.1f}->"
                            f"{o['fleet_rank_end']:.1f} "
                            f"(frozen={o['fleet_rank_frozen']:.1f}) "
                            f"ovh={o['fleet_distill_overhead_pct']:.0f}%",
                  values=("fleet_distill_overhead_pct",))
        else:
            timed("fig1_2_orientation_gains", bench_orientation_gains.run,
                  lambda o: f"dyn_over_fixed="
                            f"+{o['dyn_over_fixed']*100:.1f}%")
            timed("fig3_7_9_10_11_scene_stats", bench_scene_stats.run,
                  lambda o: f"corr1hop={o['corr_1hop']:.2f}")
            timed("fig12_13_14_e2e_sweeps", bench_e2e_sweeps.run,
                  lambda o: f"fps1_win=+{o['fps1_win']*100:.1f}%")
            timed("fig15_table2_sota", bench_sota.run,
                  lambda o: f"madeye={o['madeye']:.3f}")
            timed("table1_fixed_cameras", bench_fixed_cameras.run,
                  lambda o: "madeye1_reduction="
                            f"{o['madeye1']['reduction']:.1f}x")
            timed("fig16_rank_quality", bench_rank_quality.run,
                  lambda o: f"median_rank={o['detector_median_rank']:.1f} "
                            f"fleet_det={o['fleet_det_median_rank']:.1f}")
            timed("fleet_learning_curve",
                  lambda: bench_rank_quality.fleet_learning_curve(
                      quick=False),
                  lambda o: f"rank={o['fleet_rank_start']:.1f}->"
                            f"{o['fleet_rank_end']:.1f} "
                            f"(frozen={o['fleet_rank_frozen']:.1f}, "
                            f"k9_end={o['fleet_rank_end_k9']:.1f}) "
                            f"ovh={o['fleet_distill_overhead_pct']:.0f}%",
                  values=("fleet_distill_overhead_pct",))
            timed("sec5_4_deepdive", bench_deepdive.run,
                  lambda o: f"path_us={o['path_us']:.0f}")
            timed("fleet_scale_controller", bench_fleet_scale.run,
                  lambda o: f"speedup={o['speedup']:.0f}x"
                            f"@{o['cameras']}x{o['steps']}")
            timed("scene_device_vs_host_tables", bench_scene_device.run,
                  lambda o: f"hetero_speedup={o['hetero_speedup']:.0f}x"
                            f"@{o['cameras']}x{o['steps']}")
            timed("detector_in_step", bench_detector_step.run,
                  lambda o: f"det_cps256={o['det_cps_256']:.0f} "
                            f"short_cps256={o['det_short_cps_256']:.0f} "
                            f"overhead={o['det_short_overhead_256']:.1f}x "
                            f"fusion={o['batch_fusion_speedup_256']:.2f}x "
                            f"mx={o['metrics_overhead_256']:.2f}x")
            timed("telemetry_stream",
                  lambda: telemetry_stream(quick=False),
                  lambda o: f"events={o['events']} "
                            f"median_rank={o['median_rank']:.1f}")
            timed("roofline_single", lambda: bench_roofline.run("single"),
                  lambda o: f"cells={len(o)}")
            timed("roofline_multi", lambda: bench_roofline.run("multi"),
                  lambda o: f"cells={len(o)}")

    trace_path = os.environ.get("BENCH_TRACE", "BENCH_trace.json")
    with tracing(trace_path):
        run_all()

    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
    path = os.environ.get(
        "BENCH_JSON", "BENCH_repro.quick.json" if quick
        else "BENCH_repro.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    hist = append_history(rows, quick)
    print(f"\nwrote {len(rows)} rows to {path}; trace -> {trace_path}; "
          f"history -> {hist}")


if __name__ == "__main__":
    main()
