"""Roofline analysis (§Roofline deliverable).

Reads the dry-run artifacts (dryrun_results.json, produced by
`python -m repro.launch.dryrun --all --both-meshes --out ...`) and derives
the three per-cell roofline terms for TPU v5e:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis() reports post-SPMD *per-device* numbers — verified with a
controlled sharded-matmul experiment; collective bytes are parsed from the
per-device optimized HLO.)

Also reports MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import json
import os


from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

RESULTS = os.environ.get("DRYRUN_JSON", "dryrun_results.json")
ANALYSIS = os.environ.get("ANALYSIS_JSON", "roofline_analysis.json")

# analytic active-param counts (billions) for MODEL_FLOPS
_PARAMS_B = {
    "kimi-k2-1t-a32b": (1043.0, 32.6),     # (total, active)
    "deepseek-v3-671b": (671.0, 37.0),
    "stablelm-12b": (12.1, 12.1),
    "stablelm-3b": (2.8, 2.8),
    "flux-dev": (11.9, 11.9),
    "dit-l2": (0.46, 0.46),
    "vit-b16": (0.086, 0.086),
    "swin-b": (0.088, 0.088),
    "vit-h14": (0.63, 0.63),
    "vit-s16": (0.022, 0.022),
}


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole cell (global, fwd[+bwd])."""
    cfg = get_config(arch)
    shape = get_shape(cfg, shape_name)
    total_b, active_b = _PARAMS_B[arch]
    n_active = active_b * 1e9
    if cfg.family == "lm":
        tokens = shape.global_batch * max(shape.seq_len, 1)
        if shape.kind == "train":
            return 6.0 * n_active * tokens
        if shape.kind == "prefill":
            return 2.0 * n_active * tokens
        return 2.0 * n_active * shape.global_batch   # decode: 1 tok/seq
    if cfg.family == "vision":
        if cfg.swin:
            # hierarchical: stage s sees (res/4/2^s)^2 tokens with its own
            # param count — 2 * sum_s params_s * tokens_s
            f_img = 0.0
            res0 = shape.img_res // cfg.patch
            for s, (depth, dim) in enumerate(zip(cfg.depths, cfg.dims)):
                toks = (res0 // (2 ** s)) ** 2
                params_s = depth * (4 * dim * dim + 2 * dim * 4 * dim)
                f_img += 2.0 * params_s * toks
            f = f_img * shape.global_batch
        else:
            # 2 * params * tokens per image (patch tokens + CLS)
            n_tok = (shape.img_res // cfg.patch) ** 2 + 1
            f = 2.0 * n_active * n_tok * shape.global_batch
        return 3.0 * f if shape.kind == "train" else f
    # diffusion: one forward per sampler step over latent tokens
    lat = cfg.latent_res or cfg.img_res // 8
    if cfg.latent_res and shape.img_res:
        lat = cfg.latent_res * shape.img_res // cfg.img_res
    elif shape.img_res:
        lat = shape.img_res // 8
    n_tok = (lat // cfg.patch) ** 2
    f = 2.0 * n_active * n_tok * shape.global_batch
    if shape.kind == "train":
        return 3.0 * f
    return f * shape.steps


def roofline_row(rec: dict) -> dict:
    chips = rec["chips"]
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_total"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["flops"] * chips
    return {
        **rec,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_global, 1.0),
        "roofline_frac": t_compute / max(t_compute, t_memory, t_coll),
    }


def run(mesh: str = "single") -> list | None:
    """Returns the roofline rows, or None when the dry-run artifacts are
    absent — the driver (benchmarks/run.py) treats None as *skipped* and
    records no row, instead of a meaningless cells=0 measurement
    polluting BENCH_repro.json."""
    if not os.path.exists(RESULTS):
        print(f"  [skipped] {RESULTS} not found — run the dry-run first")
        return None
    with open(RESULTS) as f:
        results = json.load(f)
    # prefer exact unrolled-extrapolated metrics where available
    analysis = {}
    if os.path.exists(ANALYSIS):
        with open(ANALYSIS) as f:
            analysis = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        if "error" in rec or not key.endswith(f"|{mesh}"):
            continue
        a = analysis.get(key)
        if a and "error" not in a:
            rec = {**rec, "flops": a["flops"],
                   "bytes_accessed": a["bytes_accessed"],
                   "collective_total": a["collective_total"],
                   "exact": True}
        rows.append(roofline_row(rec))

    print(f"\n== Roofline ({mesh}-pod mesh) ==")
    print(f"  {'arch':<17} {'shape':<12} {'compute':>9} {'memory':>9} "
          f"{'coll':>9} {'bound':>7} {'useful':>7} {'roofl%':>7}")
    for r in rows:
        print(f"  {r['arch']:<17} {r['shape']:<12} "
              f"{r['t_compute_s']*1e3:8.2f}m {r['t_memory_s']*1e3:8.2f}m "
              f"{r['t_collective_s']*1e3:8.2f}m {r['bottleneck']:>7} "
              f"{min(r['useful_ratio'],9.99):7.2f} "
              f"{r['roofline_frac']*100:6.1f}%")
    return rows


if __name__ == "__main__":
    run("single")
    run("multi")
