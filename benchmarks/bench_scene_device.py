"""Device-resident scene substrate vs host-materialized tables.

The tables-backed episode pays an O(E * N * Z * P) numpy materialization
(procedural scene -> teacher detections -> EpisodeTables) before the scan
can start, and every camera shares that one world. The scene-backed
provider (repro.scene_jax) generates per-camera observations inside the
jit'd scan — zero host tables, per-camera scene configs and network
traces. This benchmark runs BOTH paths end-to-end at >= 512 cameras and
reports substrate-preparation time, steady-state scan throughput, the
end-to-end speedup (prep + scan) of the device path against ONE shared
host world, and `hetero_speedup` against what the host path would cost
for the per-camera worlds the device path actually simulated (one table
build per camera, extrapolated).

  PYTHONPATH=src python -m benchmarks.bench_scene_device
"""
from __future__ import annotations

import os
import time

import numpy as np

N_CAMERAS = 512
N_STEPS = 32
FPS = 3.0
SEED = 3


def _workload():
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def run(n_cameras: int = N_CAMERAS, n_steps: int = N_STEPS,
        quick: bool | None = None) -> dict:
    import jax

    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig
    from repro.fleet import FleetRunSpec, prepare_fleet_run

    if quick is None:
        quick = os.environ.get("BENCH_QUICK", "") == "1"
    if quick:
        n_cameras, n_steps = 16, 6

    grid = DEFAULT_GRID
    wl = _workload()
    budget = BudgetConfig(fps=FPS)

    # -- host path: numpy scene + teachers -> EpisodeTables, then scan
    host = prepare_fleet_run(FleetRunSpec.from_objects(
        "tables", n_cameras=n_cameras, n_steps=n_steps, seed=SEED,
        grid=grid, workload=wl, budget=budget))
    host_prep_s = host.build_s
    jax.block_until_ready(host.episode())  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(host.episode())
    host_scan_s = time.perf_counter() - t0

    # -- device path: per-camera scenes + nets generated inside the scan
    dev = prepare_fleet_run(FleetRunSpec.from_objects(
        "scene", n_cameras=n_cameras, n_steps=n_steps, seed=SEED,
        grid=grid, workload=wl, budget=budget,
        person_speed=np.linspace(0.8, 2.0, n_cameras),
        n_people=np.linspace(4, 14, n_cameras).astype(int),
        mbps=np.full(n_cameras, 24.0), net_seed=SEED))
    jax.block_until_ready(dev.provider.state0)
    dev_prep_s = dev.build_s
    t0 = time.perf_counter()
    jax.block_until_ready(dev.episode())  # compile
    dev_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, out = jax.block_until_ready(dev.episode())
    dev_scan_s = time.perf_counter() - t0

    cps = n_cameras * n_steps
    # the host path materialized ONE world shared by every camera; giving
    # each camera its own scene (what the device path actually ran) costs
    # the host path n_cameras table builds — extrapolated, not timed
    host_hetero_s = host_prep_s * n_cameras + host_scan_s
    return {
        "cameras": n_cameras,
        "steps": n_steps,
        "host_prep_s": float(host_prep_s),
        "host_scan_s": float(host_scan_s),
        "host_cps": float(cps / (host_prep_s + host_scan_s)),
        "dev_prep_s": float(dev_prep_s),
        "dev_compile_s": float(dev_compile_s),
        "dev_scan_s": float(dev_scan_s),
        "dev_cps": float(cps / (dev_prep_s + dev_scan_s)),
        "e2e_speedup": float((host_prep_s + host_scan_s)
                             / (dev_prep_s + dev_scan_s)),
        "hetero_speedup": float(host_hetero_s / (dev_prep_s + dev_scan_s)),
        "mean_shape": float(np.asarray(out.n_explored, float).mean()),
    }


if __name__ == "__main__":
    out = run()
    for k, v in out.items():
        print(f"{k:14s} {v:.2f}" if isinstance(v, float) else
              f"{k:14s} {v}")
