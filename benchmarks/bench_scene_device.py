"""Device-resident scene substrate vs host-materialized tables.

The tables-backed episode pays an O(E * N * Z * P) numpy materialization
(procedural scene -> teacher detections -> EpisodeTables) before the scan
can start, and every camera shares that one world. The scene-backed
provider (repro.scene_jax) generates per-camera observations inside the
jit'd scan — zero host tables, per-camera scene configs and network
traces. This benchmark runs BOTH paths end-to-end at >= 512 cameras and
reports substrate-preparation time, steady-state scan throughput, the
end-to-end speedup (prep + scan) of the device path against ONE shared
host world, and `hetero_speedup` against what the host path would cost
for the per-camera worlds the device path actually simulated (one table
build per camera, extrapolated).

  PYTHONPATH=src python -m benchmarks.bench_scene_device
"""
from __future__ import annotations

import os
import time

import numpy as np

N_CAMERAS = 512
N_STEPS = 32
FPS = 3.0
SEED = 3


def _workload():
    from repro.launch.serve import DEFAULT_WORKLOAD
    return DEFAULT_WORKLOAD


def run(n_cameras: int = N_CAMERAS, n_steps: int = N_STEPS,
        quick: bool | None = None) -> dict:
    import jax

    from repro.core import DEFAULT_GRID
    from repro.core.tradeoff import BudgetConfig
    from repro.data import SceneConfig, build_video
    from repro.fleet import (
        build_episode_tables,
        fleet_config,
        fleet_statics,
        init_fleet,
        make_scene_provider,
        run_fleet_episode,
        workload_spec,
    )
    from repro.serving import NetworkTrace, detection_tables

    if quick is None:
        quick = os.environ.get("BENCH_QUICK", "") == "1"
    if quick:
        n_cameras, n_steps = 16, 6

    grid = DEFAULT_GRID
    wl = _workload()
    budget = BudgetConfig(fps=FPS)
    cfg = fleet_config(grid, budget)
    spec = workload_spec(wl)
    statics = fleet_statics(grid)
    stride = max(1, int(round(15 / FPS)))

    # -- host path: numpy scene + teachers -> EpisodeTables, then scan
    t0 = time.perf_counter()
    video = build_video(grid, SceneConfig(fps=15, seed=SEED),
                        (n_steps * stride + 2) / 15.0)
    tables = detection_tables(video, wl)
    trace = NetworkTrace.fixed(24.0, 20.0, video.n_frames)
    ep = build_episode_tables(video, wl, tables, budget, trace,
                              max_steps=n_steps)
    host_prep_s = time.perf_counter() - t0
    state_h = init_fleet(grid, n_cameras)
    jax.block_until_ready(
        run_fleet_episode(cfg, spec, statics, state_h, ep))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run_fleet_episode(cfg, spec, statics, state_h, ep))
    host_scan_s = time.perf_counter() - t0

    # -- device path: per-camera scenes + nets generated inside the scan
    t0 = time.perf_counter()
    provider, state_d = make_scene_provider(
        grid, wl, cfg, n_cameras=n_cameras, n_steps=n_steps, seed=SEED,
        person_speed=np.linspace(0.8, 2.0, n_cameras),
        n_people=np.linspace(4, 14, n_cameras).astype(int),
        mbps=np.full(n_cameras, 24.0), net_seed=SEED)
    jax.block_until_ready(provider.state0)
    dev_prep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(
        run_fleet_episode(cfg, spec, statics, state_d, provider))  # compile
    dev_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, out = jax.block_until_ready(
        run_fleet_episode(cfg, spec, statics, state_d, provider))
    dev_scan_s = time.perf_counter() - t0

    cps = n_cameras * n_steps
    # the host path materialized ONE world shared by every camera; giving
    # each camera its own scene (what the device path actually ran) costs
    # the host path n_cameras table builds — extrapolated, not timed
    host_hetero_s = host_prep_s * n_cameras + host_scan_s
    return {
        "cameras": n_cameras,
        "steps": n_steps,
        "host_prep_s": float(host_prep_s),
        "host_scan_s": float(host_scan_s),
        "host_cps": float(cps / (host_prep_s + host_scan_s)),
        "dev_prep_s": float(dev_prep_s),
        "dev_compile_s": float(dev_compile_s),
        "dev_scan_s": float(dev_scan_s),
        "dev_cps": float(cps / (dev_prep_s + dev_scan_s)),
        "e2e_speedup": float((host_prep_s + host_scan_s)
                             / (dev_prep_s + dev_scan_s)),
        "hetero_speedup": float(host_hetero_s / (dev_prep_s + dev_scan_s)),
        "mean_shape": float(np.asarray(out.n_explored, float).mean()),
    }


if __name__ == "__main__":
    out = run()
    for k, v in out.items():
        print(f"{k:14s} {v:.2f}" if isinstance(v, float) else
              f"{k:14s} {v}")
