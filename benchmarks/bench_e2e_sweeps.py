"""Figures 12/13/14: MadEye vs oracle schemes across fps and networks,
plus the per-task/object win breakdown."""
from __future__ import annotations


from benchmarks import common
from repro.core import Query, Workload
from repro.core.tradeoff import BudgetConfig
from repro.serving import NetworkTrace
from repro.serving.pipeline import run_madeye, run_scheme


def _run_cell(cache, wl, fps, mbps, rtt_ms, *, pipelined=False):
    video, tables = cache.video, cache.tables
    acc = cache.workload(wl)
    trace = NetworkTrace.fixed(mbps, rtt_ms, video.n_frames)
    b = BudgetConfig(fps=fps, pipelined=pipelined)
    m = run_madeye(video, wl, tables, b, trace, acc_table=acc)
    bf = run_scheme(video, wl, tables, "best_fixed", budget=b,
                    acc_table=acc)
    bd = run_scheme(video, wl, tables, "best_dynamic", budget=b,
                    acc_table=acc)
    return m.accuracy, bf.accuracy, bd.accuracy


def run(workload_names=("W1", "W4", "W7")) -> dict:
    out = {}
    print("\n== Fig 12: fps sweep @ {24 Mbps, 20 ms} ==")
    for fps in (1, 5, 15, 30):
        wins, gaps = [], []
        for seed in common.VIDEO_SEEDS:
            cache = common.acc_cache(seed)
            for w in workload_names:
                m, bf, bd = _run_cell(cache, common.WORKLOADS[w], fps, 24, 20)
                wins.append(m - bf)
                gaps.append(bd - m)
        wm, _, _ = common.median_iqr(wins)
        gm, _, _ = common.median_iqr(gaps)
        print(f"  fps={fps:>2}: MadEye-best_fixed=+{wm*100:.1f}%  "
              f"best_dynamic-MadEye={gm*100:.1f}%")
        out[f"fps{fps}_win"] = wm

    print("== Fig 13: network sweep @ 15 fps ==")
    for mbps, rtt in ((24, 20), (40, 10), (60, 5)):
        wins = []
        for seed in common.VIDEO_SEEDS:
            cache = common.acc_cache(seed)
            for w in workload_names:
                m, bf, _ = _run_cell(cache, common.WORKLOADS[w], 15, mbps,
                                     rtt)
                wins.append(m - bf)
        wm, _, _ = common.median_iqr(wins)
        print(f"  {{{mbps} Mbps, {rtt} ms}}: win=+{wm*100:.1f}%")
        out[f"net{mbps}_win"] = wm

    print("== Fig 14: win by task and object (5 fps) ==")
    for task in ("binary", "count", "detect", "agg_count"):
        for obj in ("person", "car"):
            if task == "agg_count" and obj == "car":
                continue
            wins = []
            for seed in common.VIDEO_SEEDS:
                cache = common.acc_cache(seed)
                wl = Workload((Query("yolov4", obj, task),))
                m, bf, _ = _run_cell(cache, wl, 5, 24, 20)
                wins.append(m - bf)
            wm, _, _ = common.median_iqr(wins)
            print(f"  {task:>10}/{obj:<6}: win=+{wm*100:.1f}%")
            out[f"{task}_{obj}_win"] = wm
    return out


if __name__ == "__main__":
    run()
